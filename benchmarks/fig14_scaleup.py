"""Fig. 14 — bursty load: average TTFT / TPOT for different pipeline group
sizes when N concurrent requests hit one cold model (Llama2-13B on V100s,
max batch 8)."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.generator import ModelInstance, burst


def burst_run(n_requests: int, group_s: int):
    # TPOT SLO forces full-memory pipeline workers (paper Fig.14b: TPOT
    # overhead only 1.08-1.19x => their groups are full-memory)
    inst = ModelInstance("fig14#0", "chatbot-13b", "llama2-13b",
                         slo_ttft=1e6, slo_tpot=0.12,
                         mean_prompt=512, mean_output=512)
    sim = ServerlessSim(testbed_i(), profiles(), [inst], system="hydra",
                        force_s=group_s, consolidate=True)
    reqs = burst(inst, n_requests)
    sim.submit(reqs)
    sim.run(until=3600)
    done = [r for r in reqs if r.completion is not None]
    ttft = sum(r.ttft for r in done) / len(done)
    tpot = sum(r.tpot for r in done) / len(done)
    return ttft, tpot, len(done)


def run(bench: Bench, loads=(16, 64, 128)):
    for n in loads:
        base = None
        for s in (1, 2, 4):
            ttft, tpot, n_done = burst_run(n, s)
            derived = f"tpot={tpot*1e3:.0f}ms;done={n_done}"
            if s == 1:
                base = ttft
            else:
                derived += f";ttft_speedup={base/ttft:.2f}x"
            bench.add(f"fig14/burst{n}/s{s}", ttft, derived)


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
