"""Engine micro-benchmark: prefill latency and decode throughput of the
real JAX serving engine on the reduced CPU config — contiguous vs paged
KV layout, plus the paged engine's prefix cache (shared-prefix workload:
prefill-FLOP and pool-occupancy win), chunked prefill (mixed
prefill+decode steps bounding per-step latency while a long prompt
prefills), and the scheduler policies under an *overload* workload
(arrival burst beyond the endpoint's slot capacity, mixed
priorities/SLOs): per-policy TTFT-SLO attainment shows FCFS head-of-line
blocking starving tight-deadline requests while the priority and
SLO-deadline (EDF) policies reorder — and preempt background residents,
resuming them through the prefix cache — to hit their budgets. Writes
``BENCH_engine.json`` (path overridable via argv[1]) so the perf
trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_engine.py [out.json]
"""

from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.types import SLO
from repro.kernels import ops as kops
from repro.models import build_model
from repro.models.attention import paged_kv_token_bytes
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine

BATCH = 4
PROMPT_LEN = 16
N_DECODE = 16
BLOCK = 8
SHARED_LEN = 32          # system-prompt prefix shared by every request
TAIL_LEN = 8
LONG_PROMPT = 64
CHUNK = 8
POLICIES = ("fcfs", "priority", "slo")
DECODE_MODES = ("gather", "fused", "fused_fp16", "fused_int8")


def bench_layout(cfg, params, paged: bool) -> dict:
    ep = ServingEndpoint(Engine(cfg, [params], max_batch=BATCH,
                                max_seq=96, paged=paged))
    # max_new keeps every request resident past the timed window, so the
    # measured steps are pure full-batch decode (no finish/clear_slot cost)
    for i in range(BATCH):
        ep.submit([1 + i] * PROMPT_LEN,
                  SamplingParams(max_new=N_DECODE + 4))
    # step 1 = BATCH prefills + the first batched decode, both cold (the
    # engine decodes newly admitted requests in the same step), so this
    # number includes prefill AND decode jit compiles
    t0 = time.perf_counter()
    ep.step()
    first_step_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(N_DECODE):
        ep.step()
    decode_s = time.perf_counter() - t0
    return {
        "layout": "paged" if paged else "contiguous",
        "batch": BATCH,
        "prompt_len": PROMPT_LEN,
        "first_step_cold_s": first_step_cold_s,
        "decode_steps_per_s": N_DECODE / decode_s,
        "decode_step_ms": decode_s / N_DECODE * 1e3,
    }


def bench_prefix_sharing(cfg, params, prefix_cache: bool) -> dict:
    """BATCH requests sharing a SHARED_LEN-token system prompt. With the
    prefix cache, every request after the first prefills only its tail:
    the FLOP win is the cached-token count, the memory win the deduped
    pool occupancy."""
    eng = Engine(cfg, [params], max_batch=BATCH, max_seq=96,
                 block_size=BLOCK, paged=True, prefix_cache=prefix_cache)
    shared = list(range(2, 2 + SHARED_LEN))
    reqs = [eng.submit(shared + [100 + i] * TAIL_LEN,
                       SamplingParams(max_new=8)) for i in range(BATCH)]
    t0 = time.perf_counter()
    eng.step()                    # all BATCH prompts prefill here
    prefill_step_s = time.perf_counter() - t0
    bm = eng.block_mgr
    blocks_in_use = bm.n_blocks - bm.free_blocks   # referenced right now
    blocks_no_sharing = sum(len(bm.tables[r.rid].blocks) for r in reqs)
    eng.run()
    prompt_tokens = sum(r.prompt_total for r in reqs)
    cached = sum(r.metrics.cached_tokens for r in reqs)
    return {
        "workload": "shared-prefix",
        "prefix_cache": prefix_cache,
        "batch": BATCH,
        "shared_prefix_len": SHARED_LEN,
        "prompt_tokens_total": prompt_tokens,
        "cached_tokens_total": cached,
        "prefill_tokens_computed": prompt_tokens - cached,
        "pool_blocks_used": blocks_in_use,
        "pool_blocks_without_sharing": blocks_no_sharing,
        "prefill_step_s": prefill_step_s,
        "cache_hit_tokens": bm.cache_hit_tokens,
        "evictions": bm.evictions,
    }


def bench_chunked_prefill(cfg, params, chunk) -> dict:
    """BATCH-1 short requests decode while one LONG_PROMPT request
    arrives. Monolithic prefill stalls every decode for a full forward;
    chunked prefill bounds the per-step work (mixed steps)."""
    eng = Engine(cfg, [params], max_batch=BATCH, max_seq=96,
                 block_size=BLOCK, paged=True, prefill_chunk=chunk)
    shorts = [eng.submit([1 + i] * 4, SamplingParams(max_new=40))
              for i in range(BATCH - 1)]
    for _ in range(2):            # shorts are warm and decoding
        eng.step()
    long_req = eng.submit(list(range(3, 3 + LONG_PROMPT)),
                          SamplingParams(max_new=4))
    short_before = sum(len(r.generated) for r in shorts)
    step_ms, mixed_steps = [], 0
    while not long_req.prefill_done:
        t0 = time.perf_counter()
        out = eng.step()
        step_ms.append((time.perf_counter() - t0) * 1e3)
        if out.prefill_tokens and out.events:
            mixed_steps += 1
    short_during = sum(len(r.generated) for r in shorts) - short_before
    eng.run()
    return {
        "workload": "chunked-prefill",
        "prefill_chunk": chunk,
        "long_prompt_len": LONG_PROMPT,
        "decode_batch": BATCH - 1,
        "prefill_steps": len(step_ms),
        "mixed_steps": mixed_steps,
        "max_step_ms_during_prefill": max(step_ms),
        "mean_step_ms_during_prefill": sum(step_ms) / len(step_ms),
        "long_ttft_steps": long_req.metrics.ttft_steps,
        "short_tokens_during_prefill": short_during,
    }


def bench_overload(cfg, params, policy: str) -> dict:
    """Overload burst: two loose-SLO background requests saturate both
    slots, then three tight-TTFT interactive requests arrive at once —
    more work than the endpoint can hold. FCFS serves in arrival order
    (interactive TTFTs blow their budgets behind the long decodes);
    priority/EDF admit the urgent requests first, preempting background
    residents whose blocks are released but whose committed prefix stays
    cached, so the resumes re-prefill only their tails."""
    eng = Engine(cfg, [params], max_batch=2, max_seq=96, block_size=BLOCK,
                 paged=True, prefix_cache=True, policy=policy)
    background = [
        eng.submit([10 + i] * 24,
                   SamplingParams(max_new=24, priority=0,
                                  slo=SLO(ttft=200.0, tpot=60.0)))
        for i in range(2)]
    for _ in range(3):                    # background is warm and decoding
        eng.step()
    interactive = [
        eng.submit([50 + i] * 4,
                   SamplingParams(max_new=4, priority=2,
                                  slo=SLO(ttft=6.0, tpot=30.0)))
        for i in range(3)]
    eng.run()
    reqs = background + interactive
    attained = [r.metrics.ttft_steps is not None
                and r.metrics.ttft_steps <= r.params.slo.ttft for r in reqs]
    resumed_cached = sum(r.metrics.cached_tokens for r in reqs
                         if r.metrics.preemptions)
    return {
        "workload": "overload-burst",
        "policy": policy,
        "n_background": len(background),
        "n_interactive": len(interactive),
        "ttft_slo_attainment": sum(attained) / len(reqs),
        "interactive_ttft_steps": [r.metrics.ttft_steps
                                   for r in interactive],
        "preemptions": eng.scheduler.n_preemptions,
        "resumed_cached_tokens": resumed_cached,
    }


def _pool_bytes_per_token(eng) -> float:
    """Measured KV pool bytes per token slot, every leaf (int8 pages +
    their f32 scale/zero) across all stages and attention periods."""
    total = 0
    for w in eng.runner.workers:
        for sub in w.cache.values():
            if "k_pages" in sub:
                total += sum(int(a.nbytes) for a in sub.values())
    w0 = eng.runner.workers[0]
    return total / (w0.n_pages * w0.page_size)


def bench_decode_mode(cfg, params, mode: str) -> dict:
    """Steady-state decode throughput of one engine mode over a staggered
    mixed workload: ``gather`` is the legacy paged step (per-request
    prefill forwards + one batched paged-decode), the ``fused*`` modes run
    every step as fused ragged launches, at fp32/fp16/int8 KV storage.
    p50/p99 step latency over the timed decode window; KV bytes/token
    both analytic (attention.paged_kv_token_bytes) and measured off the
    live pools — the accounting satellite asserts they agree exactly."""
    kv_dtype = {"fused_fp16": "float16", "fused_int8": "int8"}.get(mode)
    eng = Engine(cfg, [params], max_batch=BATCH, max_seq=96,
                 block_size=BLOCK, paged=True, prefill_chunk=CHUNK,
                 kv_dtype=kv_dtype, fused=mode != "gather")
    for i in range(BATCH):     # staggered lengths: a genuinely ragged mix
        eng.submit([1 + i] * (10 + 3 * i), SamplingParams(max_new=48))
    while any(not r.prefill_done for r in eng.active()):
        eng.step()             # warmup: chunked prefills + early decodes
    for _ in range(3):
        eng.step()             # decode shapes compiled, caches warm
    times, toks = [], 0
    for _ in range(N_DECODE):
        t0 = time.perf_counter()
        out = eng.step()
        times.append(time.perf_counter() - t0)
        toks += len(out.events)
    ts = sorted(times)
    analytic = paged_kv_token_bytes(cfg, kv_dtype) * eng.n_attn_layers()
    return {
        "workload": "decode-throughput",
        "mode": mode,
        "kv_dtype": kv_dtype or str(cfg.dtype),
        "batch": BATCH,
        "decode_tokens_per_s": toks / sum(times),
        "p50_step_ms": ts[len(ts) // 2] * 1e3,
        "p99_step_ms": ts[min(len(ts) - 1, int(len(ts) * 0.99))] * 1e3,
        "kv_bytes_per_token_analytic": analytic,
        "kv_bytes_per_token_measured": _pool_bytes_per_token(eng),
    }


def bench_fused_launch(cfg, params) -> dict:
    """The tentpole claim at op level: ONE fused ragged launch serving a
    whole mixed batch vs the per-request gather baseline (one
    paged-decode launch per request over the same pools). Same math, same
    tokens — the fused row amortizes launch/dispatch across the batch."""
    rng = np.random.RandomState(0)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    bs, nb = BLOCK, 96 // BLOCK + 1
    n_pages = BATCH * nb + 1
    k_pages = jnp.asarray(rng.randn(n_pages, bs, hkv, hd), jnp.float32)
    v_pages = jnp.asarray(rng.randn(n_pages, bs, hkv, hd), jnp.float32)
    tables = jnp.asarray(
        np.arange(BATCH * nb, dtype=np.int32).reshape(BATCH, nb))
    hist = [9 + 8 * i for i in range(BATCH)]      # ragged histories
    q = jnp.asarray(rng.randn(BATCH, hq, hd), jnp.float32)

    per_req = jax.jit(lambda qb, bt, kl: kops.paged_decode_attention(
        qb, k_pages, v_pages, bt, kl))
    tile = 8
    row = jnp.asarray(np.repeat(np.arange(BATCH, dtype=np.int32), tile))
    pos = np.full(BATCH * tile, -1, np.int32)
    pos[::tile] = hist
    pos = jnp.asarray(pos)
    qrag = jnp.zeros((BATCH * tile, hq, hd), jnp.float32)
    qrag = qrag.at[::tile].set(q)
    fused = jax.jit(lambda qf: kops.ragged_paged_attention(
        qf, k_pages, v_pages, tables, row, pos))

    for _ in range(2):        # compile + warm both
        for b in range(BATCH):
            per_req(q[b:b + 1, None], tables[b:b + 1],
                    jnp.asarray([hist[b] + 1])).block_until_ready()
        fused(qrag).block_until_ready()
    iters = 30
    t0 = time.perf_counter()
    for _ in range(iters):
        for b in range(BATCH):
            out = per_req(q[b:b + 1, None], tables[b:b + 1],
                          jnp.asarray([hist[b] + 1]))
        out.block_until_ready()
    gather_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fused(qrag)
    out.block_until_ready()
    fused_s = time.perf_counter() - t0
    return {
        "workload": "fused-launch-vs-per-request-gather",
        "batch": BATCH,
        "launches_per_step_gather": BATCH,
        "launches_per_step_fused": 1,
        "gather_tokens_per_s": BATCH * iters / gather_s,
        "fused_tokens_per_s": BATCH * iters / fused_s,
    }


def main(out_path: str = "BENCH_engine.json"):
    cfg = smoke_variant(get_config("granite-3-8b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    results = [bench_layout(cfg, params, paged) for paged in (False, True)]
    prefix = [bench_prefix_sharing(cfg, params, pc) for pc in (False, True)]
    chunked = [bench_chunked_prefill(cfg, params, c) for c in (None, CHUNK)]
    overload = [bench_overload(cfg, params, pol) for pol in POLICIES]
    decode = [bench_decode_mode(cfg, params, m) for m in DECODE_MODES]
    launch = bench_fused_launch(cfg, params)
    # quantized-KV byte quote at PRODUCTION geometry (head_dim=128): the
    # smoke config's head_dim=16 inflates the f32 scale/zero overhead, so
    # the "halves bytes/token" claim is stated where it holds
    full = get_config("granite-3-8b")
    kv_full = {
        "workload": "kv-bytes-per-token-full-config",
        "model": full.name,
        "head_dim": full.head_dim,
        "fp16_bytes": paged_kv_token_bytes(full, "float16"),
        "int8_bytes": paged_kv_token_bytes(full, "int8"),
    }
    kv_full["int8_over_fp16"] = kv_full["int8_bytes"] / kv_full["fp16_bytes"]
    report = {
        "bench": "engine-smoke",
        "model": cfg.name,
        "device": jax.devices()[0].platform,
        "results": (results + prefix + chunked + overload + decode
                    + [launch, kv_full]),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for r in results:
        print(f"{r['layout']:>10}: first step (cold, prefill+decode) "
              f"{r['first_step_cold_s']*1e3:.0f}ms"
              f"  decode {r['decode_steps_per_s']:.1f} steps/s"
              f" ({r['decode_step_ms']:.1f} ms/step, batch={r['batch']})")
    for r in prefix:
        on = "on " if r["prefix_cache"] else "off"
        print(f"prefix {on}: prefill {r['prefill_tokens_computed']}/"
              f"{r['prompt_tokens_total']} tokens computed, pool "
              f"{r['pool_blocks_used']} blocks "
              f"(vs {r['pool_blocks_without_sharing']} unshared)")
    for r in chunked:
        mode = f"chunk={r['prefill_chunk']}" if r["prefill_chunk"] \
            else "monolithic"
        print(f"{mode:>10}: long-prompt prefill over "
              f"{r['prefill_steps']} steps ({r['mixed_steps']} mixed), "
              f"max step {r['max_step_ms_during_prefill']:.1f}ms, "
              f"ttft {r['long_ttft_steps']} steps")
    for r in overload:
        print(f"{r['policy']:>10}: TTFT-SLO attainment "
              f"{r['ttft_slo_attainment']:.2f}, interactive ttft "
              f"{r['interactive_ttft_steps']} steps, "
              f"{r['preemptions']} preemptions "
              f"({r['resumed_cached_tokens']} resumed tokens from cache)")
    by_pol = {r["policy"]: r["ttft_slo_attainment"] for r in overload}
    assert by_pol["slo"] > by_pol["fcfs"], \
        "SLO-deadline policy must beat FCFS on the bursty workload"
    for r in decode:
        print(f"{r['mode']:>10}: decode {r['decode_tokens_per_s']:.0f} "
              f"tok/s, p50 {r['p50_step_ms']:.2f}ms p99 "
              f"{r['p99_step_ms']:.2f}ms, KV {r['kv_bytes_per_token_analytic']}"
              f" B/tok (measured {r['kv_bytes_per_token_measured']:.0f})")
    print(f"fused launch: {launch['fused_tokens_per_s']:.0f} tok/s (1 "
          f"launch) vs per-request gather "
          f"{launch['gather_tokens_per_s']:.0f} tok/s "
          f"({launch['launches_per_step_gather']} launches)")
    print(f"kv bytes/token @ {full.name} (hd={full.head_dim}): "
          f"int8 {kv_full['int8_bytes']} / fp16 {kv_full['fp16_bytes']} "
          f"= {kv_full['int8_over_fp16']:.3f}")
    assert launch["fused_tokens_per_s"] >= launch["gather_tokens_per_s"], \
        "one fused ragged launch must beat per-request gather launches"
    assert kv_full["int8_over_fp16"] <= 0.6, \
        "int8 pages must (at least) nearly halve KV bytes/token at " \
        "production head_dim"
    for r in decode:
        assert r["kv_bytes_per_token_measured"] == \
            r["kv_bytes_per_token_analytic"], \
            f"pool bytes diverge from the analytic quote in mode {r['mode']}"
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main(*sys.argv[1:2])
