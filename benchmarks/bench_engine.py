"""Engine micro-benchmark: prefill latency and decode throughput of the
real JAX serving engine, contiguous vs paged KV layout, on the reduced
CPU config. Writes ``BENCH_engine.json`` (path overridable via argv[1])
so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_engine.py [out.json]
"""

from __future__ import annotations

import json
import sys
import time

import jax

from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine

BATCH = 4
PROMPT_LEN = 16
N_DECODE = 16


def bench_layout(cfg, params, paged: bool) -> dict:
    ep = ServingEndpoint(Engine(cfg, [params], max_batch=BATCH,
                                max_seq=96, paged=paged))
    # max_new keeps every request resident past the timed window, so the
    # measured steps are pure full-batch decode (no finish/clear_slot cost)
    for i in range(BATCH):
        ep.submit([1 + i] * PROMPT_LEN,
                  SamplingParams(max_new=N_DECODE + 4))
    # step 1 = BATCH prefills + the first batched decode, both cold (the
    # engine decodes newly admitted requests in the same step), so this
    # number includes prefill AND decode jit compiles
    t0 = time.perf_counter()
    ep.step()
    first_step_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(N_DECODE):
        ep.step()
    decode_s = time.perf_counter() - t0
    return {
        "layout": "paged" if paged else "contiguous",
        "batch": BATCH,
        "prompt_len": PROMPT_LEN,
        "first_step_cold_s": first_step_cold_s,
        "decode_steps_per_s": N_DECODE / decode_s,
        "decode_step_ms": decode_s / N_DECODE * 1e3,
    }


def main(out_path: str = "BENCH_engine.json"):
    cfg = smoke_variant(get_config("granite-3-8b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    results = [bench_layout(cfg, params, paged) for paged in (False, True)]
    report = {
        "bench": "engine-smoke",
        "model": cfg.name,
        "device": jax.devices()[0].platform,
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for r in results:
        print(f"{r['layout']:>10}: first step (cold, prefill+decode) "
              f"{r['first_step_cold_s']*1e3:.0f}ms"
              f"  decode {r['decode_steps_per_s']:.1f} steps/s"
              f" ({r['decode_step_ms']:.1f} ms/step, batch={r['batch']})")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main(*sys.argv[1:2])
