"""Benchmark runner — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,fig13] [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps for CI")
    args = ap.parse_args()

    from benchmarks import (fig8_coldstart, fig9_breakdown, fig10_cv,
                            fig11_slo, fig12_apps, fig13_scaledown,
                            fig14_scaleup, fig15_brownfield,
                            roofline_report, table1_warm)

    sections = {
        "table1": table1_warm.run,
        "fig8": fig8_coldstart.run,
        "fig9": fig9_breakdown.run,
        "fig10": (lambda b: fig10_cv.run(b, cvs=(8.0,), rates=(0.6,)))
        if args.fast else fig10_cv.run,
        "fig11": (lambda b: fig11_slo.run(b, scales=(1.0,)))
        if args.fast else fig11_slo.run,
        "fig12": fig12_apps.run,
        "fig13": fig13_scaledown.run,
        "fig14": (lambda b: fig14_scaleup.run(b, loads=(64,)))
        if args.fast else fig14_scaleup.run,
        "fig15": fig15_brownfield.run,
        "roofline": roofline_report.run,
    }
    only = [s for s in args.only.split(",") if s]
    bench = Bench()
    for name, fn in sections.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn(bench)
        except Exception as e:  # noqa: BLE001
            bench.add(f"{name}/ERROR", 0.0, repr(e)[:120])
            import traceback
            traceback.print_exc(file=sys.stderr)
        print(f"# section {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    bench.emit()


if __name__ == "__main__":
    main()
