"""Fleet control-plane benchmark: many models, one shared pool, bursty
arrivals — naive reactive scaling vs the HydraServe-style proactive
policy (Alg. 1 proactive model distribution + §6.1 predictive
prewarming + delayed downscale), all through the one shared
``FleetController``.

Two parts, both written to ``BENCH_fleet.json``:

  * ``sim``   — the discrete-event fleet: ≥8 model instances over
    testbed (i), a recurring-burst trace (every model reaped to zero
    between episodes), naive vs proactive. Reports fleet-wide
    request-experienced cold-start p50/p99 and TTFT SLO attainment;
    the proactive policy must strictly improve cold p99 and
    attainment.
  * ``real``  — the real-JAX ``FleetFrontend`` smoke: ≥4 tiny models
    on a shared server pool, concurrent cold starts through the shared
    ``FetchSchedule``, scale-to-zero and re-warm, measured cold-start
    timelines.

    PYTHONPATH=src python benchmarks/bench_fleet.py [out.json] [--sim-only]
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import profiles, testbed_i
from repro.fleet.controller import FleetPolicy
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import make_instances, periodic_bursts

# --------------------------------------------------------------------- sim
N_INSTANCES = 8          # distinct model instances sharing the pool
PERIOD = 120.0           # burst recurrence per instance
N_BURSTS = 10
BURST_SIZE = 3
KEEPALIVE = 30.0         # << PERIOD: every model reaps to zero between bursts


def fleet_sim(policy: FleetPolicy) -> dict:
    insts = make_instances(APPLICATIONS, 2)[:N_INSTANCES]
    assert len(insts) >= 8
    sim = ServerlessSim(testbed_i(), profiles(), insts, system="hydra",
                        policy=policy)
    reqs = periodic_bursts(insts, PERIOD, N_BURSTS, BURST_SIZE,
                           stagger=3.0, jitter=1.0, seed=0)
    sim.submit(reqs)
    sim.run(until=PERIOD * (N_BURSTS + 2))
    m = sim.metrics()
    assert m["n"] == len(reqs), "trace did not drain"
    return m


def run_sim() -> dict:
    naive = fleet_sim(FleetPolicy.naive(keepalive_s=KEEPALIVE))
    proactive = fleet_sim(FleetPolicy.proactive(
        keepalive_s=KEEPALIVE, downscale_extend_s=60.0,
        placement_interval_s=20.0, placement_top_k=N_INSTANCES,
        placement_fanout=2))
    assert proactive["prewarms"] > 0, "prewarming never fired"
    assert proactive["placements"] > 0, "proactive placement never fired"
    assert proactive["cold_p99"] < naive["cold_p99"], \
        f'cold p99 {proactive["cold_p99"]:.2f} !< {naive["cold_p99"]:.2f}'
    assert proactive["ttft_attainment"] > naive["ttft_attainment"], (
        f'attainment {proactive["ttft_attainment"]:.3f} !> '
        f'{naive["ttft_attainment"]:.3f}')
    return {
        "models": N_INSTANCES, "period_s": PERIOD, "bursts": N_BURSTS,
        "burst_size": BURST_SIZE, "keepalive_s": KEEPALIVE,
        "naive": naive, "proactive": proactive,
        "cold_p99_reduction": 1.0 - proactive["cold_p99"] / naive["cold_p99"],
    }


# -------------------------------------------------------------------- real
def run_real() -> dict:
    """≥4 real models on a shared pool: batched concurrent cold starts,
    queued-during-cold-start requests, scale-to-zero and bit-exact
    re-warm — through the same FleetController policy object."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.core.types import (GB, Gbps, ModelProfile, ServerSpec, SLO,
                                  TimingProfile)
    from repro.fleet import FleetFrontend
    from repro.models import build_model

    cfg = ModelConfig(name="fleet-tiny", family="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab=256, dtype="float32", max_pp=2)
    servers = [ServerSpec(f"s{i}", 10 * Gbps, 12e9, 2 * GB, 1)
               for i in range(4)]
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    ff = FleetFrontend(servers, FleetPolicy.proactive(
        keepalive_s=20.0, downscale_extend_s=20.0,
        placement_interval_s=5.0, placement_top_k=4))
    n_models = 4
    for i in range(n_models):
        prof = ModelProfile(f"m{i}", 8 * 1024 * 1024,
                            TimingProfile(t_cc=0.2, t_l=0.2, t_cu=0.1),
                            SLO(10.0, 0.5), max_pp=2,
                            kv_bytes_per_token=4 * 4 * 16 * 2 * 2)
        ff.register(cfg, prof, params=params, max_batch=2, max_seq=64)

    # burst 1: all four models cold-start concurrently (shared schedule)
    trace = [(f"m{i}", 0.0, [1 + i, 2 + i, 3 + i]) for i in range(n_models)]
    # burst 2 (after reap): every model cold again — outputs must repeat.
    # Drain past the reap window but short of t=120, where the controller
    # (correctly) prewarms for the learned 60 s burst period.
    trace += [(f"m{i}", 60.0, [1 + i, 2 + i, 3 + i]) for i in range(n_models)]
    reqs = ff.run_trace(trace, drain_to=110.0)

    first = {r.model: r.output for r in reqs if r.arrival == 0.0}
    for r in reqs:
        if r.arrival == 60.0:
            assert r.output == first[r.model], \
                f"{r.model}: re-warmed output diverged"
    assert all(not mm.slots for mm in ff.models.values()), \
        "scale-to-zero reap did not run"
    m = ff.metrics()
    assert m["cold_starts"] >= 2 * n_models
    return {"models": n_models, "bit_exact_rewarm": True, **m}


def main():
    out = sys.argv[1] if len(sys.argv) > 1 and not sys.argv[1].startswith(
        "--") else "BENCH_fleet.json"
    t0 = time.time()
    report = {"sim": run_sim()}
    if "--sim-only" not in sys.argv:
        report["real"] = run_real()
    report["wall_s"] = round(time.time() - t0, 2)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    s = report["sim"]
    print(f"fleet sim: cold_p99 naive={s['naive']['cold_p99']:.2f}s "
          f"proactive={s['proactive']['cold_p99']:.2f}s "
          f"(-{100 * s['cold_p99_reduction']:.0f}%), "
          f"attainment {s['naive']['ttft_attainment']:.3f} -> "
          f"{s['proactive']['ttft_attainment']:.3f}")
    if "real" in report:
        r = report["real"]
        print(f"fleet real: {r['models']} models, {r['cold_starts']} cold "
              f"starts, cold_p50={r['cold_p50']:.2f}s, bit-exact re-warm ok")
    print(f"wrote {out} ({report['wall_s']}s)")


if __name__ == "__main__":
    main()
