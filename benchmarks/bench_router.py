"""KV-aware routing benchmark: multi-turn chat sessions over a
replicated model — ``kv_affinity`` routing vs the ``round_robin``
baseline, plus the multi-tier spill/restore accounting cross-check.

Three parts, all written to ``BENCH_router.json``:

  * ``routing`` — one model, 3 replicas on a shared pool, a
    ``multi_turn_sessions`` trace (every turn re-sends the growing
    conversation). kv_affinity must strictly beat round_robin on the
    cached-token ratio *and* on TTFT p99 — sticking a session to the
    replica holding its KV blocks skips the re-prefill that round-robin
    pays on every replica switch.
  * ``exactness`` — the same trace on a single replica: outputs must be
    bit-exact with every multi-replica run, whatever the policy routed
    (routing moves *where* a prompt prefills, never *what* it decodes).
  * ``restore`` — spill a prefix cache through churn, restore it, and
    hold the measured restore-flow seconds against the analytic
    ``restore_estimate`` quote (same Eq. 3 bandwidth model): they must
    agree within 5%, and the restored bytes must round-trip bit-exact.

    PYTHONPATH=src python benchmarks/bench_router.py [out.json]
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

N_REPLICAS = 3
N_SESSIONS = 6
TURNS = 4
MAX_NEW = 4
VOCAB = 256


def _cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="router-tiny", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                       vocab=VOCAB, dtype="float32", max_pp=2)


def _trace():
    from repro.workloads.generator import ModelInstance, multi_turn_sessions
    inst = ModelInstance("m0", "chat", "router-tiny", 10.0, 0.5, 24, MAX_NEW)
    return multi_turn_sessions(inst, N_SESSIONS, TURNS, first_prompt=24,
                               turn_tokens=8, vocab=VOCAB,
                               session_rps=0.5, think_s=2.0, seed=0)


def _fleet(params, n_replicas, routing):
    import jax  # noqa: F401  (env already imported it)
    from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO, \
        TimingProfile
    from repro.fleet import FleetFrontend
    from repro.fleet.controller import FleetPolicy

    servers = [ServerSpec(f"s{i}", 10 * Gbps, 12e9, 2 * GB, 1)
               for i in range(2)]
    ff = FleetFrontend(servers, FleetPolicy.naive(keepalive_s=1e6))
    prof = ModelProfile("m0", 2 * 1024 * 1024,
                        TimingProfile(t_cc=0.2, t_l=0.2, t_cu=0.1),
                        SLO(10.0, 0.5), max_pp=2, kv_bytes_per_token=256)
    ff.register(_cfg(), prof, params=params, max_batch=2, max_seq=64,
                block_size=8, routing=routing)
    ff.scale_to("m0", n_replicas, now=0.0)
    return ff


def _drive(ff, trace):
    from repro.serving.api import SamplingParams
    mm = ff.models["m0"]
    t0 = max(s.ready_at for s in mm.slots) + 1.0
    out = []
    for r in trace:
        out.append(ff.submit("m0", r.prompt_ids,
                             SamplingParams(max_new=MAX_NEW),
                             now=t0 + r.arrival))
    ff.advance(t0 + trace[-1].arrival + 10.0)
    return out


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))] if xs else 0.0


def run_routing(params, trace) -> dict:
    out = {}
    for routing in ("round_robin", "kv_affinity"):
        ff = _fleet(params, N_REPLICAS, routing)
        reqs = _drive(ff, trace)
        mm = ff.metrics()["per_model"]["m0"]
        ttfts = [r.ttft for r in reqs]
        out[routing] = {
            "n": len(reqs),
            "replicas": N_REPLICAS,
            "cached_ratio": mm["cached_ratio"],
            "cached_tokens": mm["cached_tokens"],
            "restored_tokens": mm["restored_tokens"],
            "ttft_p50": _pct(ttfts, 0.50),
            "ttft_p99": _pct(ttfts, 0.99),
            "router": mm["router"],
            "kv_tier": mm["kv_tier"],
            "outputs": [r.output for r in reqs],
        }
    aff, rr = out["kv_affinity"], out["round_robin"]
    assert aff["cached_ratio"] > rr["cached_ratio"], (
        f'kv_affinity cached ratio {aff["cached_ratio"]:.3f} !> '
        f'round_robin {rr["cached_ratio"]:.3f}')
    assert aff["ttft_p99"] < rr["ttft_p99"], (
        f'kv_affinity ttft_p99 {aff["ttft_p99"]:.4f} !< '
        f'round_robin {rr["ttft_p99"]:.4f}')
    return out


def run_exactness(params, trace, routing_out) -> dict:
    """Single-replica reference: whatever the policy routed, the decoded
    tokens must match — routing is placement, not semantics."""
    ff = _fleet(params, 1, "kv_affinity")
    reqs = _drive(ff, trace)
    ref = [r.output for r in reqs]
    for routing, r in routing_out.items():
        assert r["outputs"] == ref, f"{routing} outputs diverged from the " \
            "single-replica reference"
        del r["outputs"]
    return {"n": len(ref), "bit_exact": True}


def run_restore(params) -> dict:
    """Standalone engine + KVBlockStore: churn evicts a committed prefix
    (spill), resubmitting restores it. The measured flow seconds must
    match the analytic restore_estimate quote within 5% and the decode
    must be bit-exact with a never-evicted run."""
    from repro.router import KVBlockStore, ResidencyIndex
    from repro.serving.api import SamplingParams
    from repro.serving.engine import Engine

    def fresh(kv_tier=None):
        return Engine(_cfg(), [params], max_batch=2, max_seq=64,
                      block_size=8, paged=True, prefix_cache=True,
                      kv_tier=kv_tier)

    P = list(range(1, 25))               # 3 full blocks at block_size=8
    eng_ref = fresh()
    r_ref = eng_ref.submit(P, SamplingParams(max_new=MAX_NEW))
    eng_ref.run()

    tier = KVBlockStore()                # single-server schedule, host bw
    eng = fresh(kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    r1 = eng.submit(P, SamplingParams(max_new=MAX_NEW))
    eng.run()
    assert list(r1.generated) == list(r_ref.generated)

    i = 0
    while res.match("r0", P)[0] > 0:     # churn until P fully evicted
        q = [(97 + 13 * i + j) % VOCAB for j in range(24)]
        eng.submit(q, SamplingParams(max_new=2))
        eng.run()
        i += 1
        assert i < 200, "churn never evicted the prefix"
    warm, restorable = res.match("r0", P)
    assert warm == 0 and restorable >= 3

    hashes = res.chain_hashes("r0", P)[:restorable]
    analytic = tier.restore_estimate(hashes, now=0.0)
    flows0 = len(tier.restore_flows)
    r2 = eng.submit(P, SamplingParams(max_new=MAX_NEW))
    eng.run()
    assert list(r2.generated) == list(r_ref.generated), \
        "restored decode diverged"
    measured = sum(f.seconds for f in tier.restore_flows[flows0:])
    err = abs(measured - analytic) / max(analytic, 1e-12)
    assert err <= 0.05, (
        f"restore flow accounting drifted {err:.1%} from the analytic "
        f"quote (measured {measured:.3e}s vs {analytic:.3e}s)")
    return {
        "blocks_restored": tier.restores,
        "restored_bytes": tier.restored_bytes,
        "restored_tokens": r2.metrics.restored_tokens,
        "measured_s": measured,
        "analytic_s": analytic,
        "rel_err": err,
        "bit_exact": True,
        "tier": tier.stats(),
    }


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "BENCH_router.json"
    import jax
    from repro.models import build_model
    t0 = time.time()
    params = build_model(_cfg()).init(jax.random.PRNGKey(0))
    trace = _trace()
    routing = run_routing(params, trace)
    exact = run_exactness(params, trace, routing)
    restore = run_restore(params)
    report = {
        "decode_mode": os.environ.get("REPRO_DECODE_MODE", "scatter"),
        "sessions": N_SESSIONS, "turns": TURNS,
        "routing": routing, "exactness": exact, "restore": restore,
        "wall_s": round(time.time() - t0, 2),
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    aff, rr = routing["kv_affinity"], routing["round_robin"]
    print(f"router: cached ratio rr={rr['cached_ratio']:.3f} -> "
          f"affinity={aff['cached_ratio']:.3f}, "
          f"ttft_p99 {rr['ttft_p99']:.4f}s -> {aff['ttft_p99']:.4f}s, "
          f"outputs bit-exact across {N_REPLICAS} replicas")
    print(f"restore: {restore['blocks_restored']} blocks "
          f"({restore['restored_bytes']}B) measured {restore['measured_s']:.2e}s "
          f"vs analytic {restore['analytic_s']:.2e}s "
          f"({100 * restore['rel_err']:.2f}% err)")
    print(f"wrote {out} ({report['wall_s']}s)")


if __name__ == "__main__":
    main()
