"""Fig. 11 — TTFT SLO attainment under scaled SLOs (tight ... loose),
CV fixed at 8."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import generate, make_instances

SYSTEMS = [("vllm", {}), ("serverlessllm", {}), ("hydra", {}),
           ("hydra+cache", {"cache_enabled": True})]


def run(bench: Bench, scales=(0.5, 1.0, 2.0), rps: float = 0.6,
        cv: float = 8.0):
    for scale in scales:
        for name, kw in SYSTEMS:
            insts = make_instances(APPLICATIONS, 64, slo_scale=scale)
            sim = ServerlessSim(testbed_i(), profiles(), insts,
                                system=name.split("+")[0], **kw)
            reqs = generate(insts, rps=rps, cv=cv, duration=600, seed=1)
            sim.submit(reqs)
            sim.run(until=3600)
            m = sim.metrics()
            bench.add(f"fig11/slo{scale:g}x/{name}", m["ttft_mean"],
                      f"ttft_att={m['ttft_attainment']:.3f};"
                      f"tpot_att={m['tpot_attainment']:.3f}")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
