"""Fig. 10 — TTFT SLO attainment vs request rate at several CVs, for
serverless vLLM / ServerlessLLM / HydraServe (+cache)."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import generate, make_instances

SYSTEMS = [
    ("vllm", {}),
    ("serverlessllm", {}),
    ("hydra", {}),
    ("hydra+cache", {"cache_enabled": True}),
]


def attainment(system_kw, cv: float, rps: float, seed: int = 0,
               n_per_app: int = 64, duration: float = 600.0):
    system = system_kw[0].split("+")[0]
    insts = make_instances(APPLICATIONS, n_per_app)
    sim = ServerlessSim(testbed_i(), profiles(), insts, system=system,
                        **system_kw[1])
    reqs = generate(insts, rps=rps, cv=cv, duration=duration, seed=seed)
    sim.submit(reqs)
    sim.run(until=duration * 6)
    return sim.metrics()


def run(bench: Bench, cvs=(2.0, 8.0), rates=(0.2, 0.6, 1.0)):
    for cv in cvs:
        for rps in rates:
            for name, kw in SYSTEMS:
                m = attainment((name, kw), cv, rps)
                bench.add(
                    f"fig10/cv{cv:g}/rps{rps:g}/{name}", m["ttft_mean"],
                    f"ttft_att={m['ttft_attainment']:.3f};"
                    f"tpot_att={m['tpot_attainment']:.3f};n={m['n']}")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
