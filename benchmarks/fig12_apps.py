"""Fig. 12 — per-application TTFT SLO attainment (chat / code /
summarization) at CV=8, RPS=0.6."""

from __future__ import annotations

import collections

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import generate, make_instances


def run(bench: Bench, rps: float = 0.6, cv: float = 8.0):
    for system in ("vllm", "serverlessllm", "hydra"):
        insts = make_instances(APPLICATIONS, 64)
        sim = ServerlessSim(testbed_i(), profiles(), insts, system=system)
        reqs = generate(insts, rps=rps, cv=cv, duration=600, seed=2)
        sim.submit(reqs)
        sim.run(until=3600)
        per_app = collections.defaultdict(list)
        for r in sim.finished:
            per_app[r.app.split("-")[0]].append(r)
        for app, rs in sorted(per_app.items()):
            att = sum(1 for r in rs if r.ttft_ok()) / len(rs)
            mean = sum(r.ttft for r in rs) / len(rs)
            bench.add(f"fig12/{app}/{system}", mean,
                      f"ttft_att={att:.3f};n={len(rs)}")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
