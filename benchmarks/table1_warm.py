"""Table 1 — warm-request TTFT/TPOT. Two parts:
  (a) the calibrated A10/V100 constants the simulator runs on, and
  (b) *measured* prefill/decode step latency of the real JAX engine on a
      reduced-config model (CPU), proving the serving path is real compute.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import Bench
from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine
from repro.workloads.applications import WARM


def run(bench: Bench):
    for name, w in WARM.items():
        bench.add(f"table1/{name}/warm-ttft", w.ttft, f"gpu={w.gpu}")
        bench.add(f"table1/{name}/warm-tpot", w.tpot, f"gpu={w.gpu}")

    cfg = smoke_variant(get_config("granite-3-8b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ep = ServingEndpoint(Engine(cfg, [params], max_batch=8, max_seq=96))
    for i in range(8):
        ep.submit([1 + i] * 32, SamplingParams(max_new=10))
    t0 = time.perf_counter()
    ep.step()                      # 8 prefills (batch like Table 1)
    prefill_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_dec = 8
    for _ in range(n_dec):
        ep.step()
    decode_s = (time.perf_counter() - t0) / n_dec
    bench.add("table1/engine-smoke/prefill8x32", prefill_s,
              "real JAX engine, reduced config, CPU")
    bench.add("table1/engine-smoke/decode-step", decode_s, "batch<=8")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
