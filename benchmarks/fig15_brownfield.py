"""Fig. 15 — brownfield: 5 Gbps per-function bandwidth cap, no direct TCP
between functions (inter-stage traffic relayed through storage -> doubled
t_n), Azure-like traffic on Llama2-7B/A10."""

from __future__ import annotations

import dataclasses

from benchmarks.common import Bench, profiles
from repro.core.types import GB, Gbps, ServerSpec
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import generate, make_instances


def brownfield_servers(n: int = 8):
    return [ServerSpec(f"fn-{i}", 5 * Gbps, 12e9, 24 * GB, 1)
            for i in range(n)]


def run(bench: Bench):
    profs = profiles()
    # storage-relay: double the per-hop activation time
    relay = {k: dataclasses.replace(
        v, timings=dataclasses.replace(v.timings, t_n=v.timings.t_n * 2))
        for k, v in profs.items()}
    apps = [a for a in APPLICATIONS if a.model == "llama2-7b"]
    results = {}
    for system in ("vllm", "hydra"):
        insts = make_instances(apps, 32)
        sim = ServerlessSim(brownfield_servers(), relay, insts,
                            system=system, keepalive_s=300.0)
        reqs = generate(insts, rps=0.3, cv=8.0, duration=600, seed=3)
        sim.submit(reqs)
        sim.run(until=3600)
        cold = [c for c in sim.cold_start_log]
        m = sim.metrics()
        results[system] = m
        bench.add(f"fig15/{system}", m["ttft_mean"],
                  f"ttft_att={m['ttft_attainment']:.3f};"
                  f"colds={m['cold_starts']}")
    speed = results["vllm"]["ttft_mean"] / results["hydra"]["ttft_mean"]
    bench.add("fig15/mean-ttft-reduction", 0.0, f"{speed:.2f}x")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
