"""Fig. 9 — incremental technique breakdown: vLLM baseline, +Prefetch,
+Stream, +Overlap, +Parallel (the paper's ablation, under 2-way NIC
contention where overlap matters most)."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.core.coldstart import OverlapFlags
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import burst, make_instances

STEPS = [
    ("vllm", dict(system="vllm")),
    ("+prefetch", dict(system="hydra", force_s=1,
                       flags=OverlapFlags(True, False, False),
                       consolidate=False)),
    ("+stream", dict(system="hydra", force_s=1,
                     flags=OverlapFlags(True, True, False),
                     consolidate=False)),
    ("+overlap", dict(system="hydra", force_s=1,
                      flags=OverlapFlags(True, True, True),
                      consolidate=False)),
    ("+parallel", dict(system="hydra", force_s=4,
                       flags=OverlapFlags(True, True, True),
                       consolidate=False)),
]


def run_real(bench: Bench, tol: float = 0.05):
    """--real-loader: execute the Fig. 9 ablation steps through the real
    data plane (a tiny on-disk ModelStore + StreamedStageLoader) and
    cross-check every measured stage span against worker_timeline's
    analytic prediction under matched bandwidths. Bandwidths are scaled
    so the tiny smoke model's fetch dominates like the paper's Fig. 1."""
    import tempfile

    import jax

    from repro.configs import get_config, smoke_variant
    from repro.models import build_model
    from repro.store import ModelStore, assert_within, crosscheck_stages
    from repro.workloads.applications import timings_for

    cfg = smoke_variant(get_config("granite-3-8b"))
    m = build_model(cfg)
    store = ModelStore.save(tempfile.mkdtemp(prefix="fig9-store-"),
                            m, m.init(jax.random.PRNGKey(0)))
    t = timings_for("llama2-13b")
    nic = store.total_bytes / 12.0            # full-model fetch ~12 s
    load_bw = nic * 4
    steps = [("baseline", 1, OverlapFlags.none()),
             ("+prefetch", 1, OverlapFlags(True, False, False)),
             ("+stream", 1, OverlapFlags(True, True, False)),
             ("+overlap", 1, OverlapFlags(True, True, True)),
             ("+parallel", min(4, cfg.n_periods), OverlapFlags.all())]
    prev = None
    for name, s, flags in steps:
        checks = crosscheck_stages(store, s, timings=t, flags=flags,
                                   nic_bytes_per_s=nic,
                                   load_bytes_per_s=load_bw)
        worst = assert_within(checks, tol)
        ready = max(c.measured.timeline.ready for c in checks)
        analytic = max(c.analytic.ready for c in checks)
        derived = (f"analytic={analytic:.2f}s,err={worst * 100:.2f}%"
                   + ("" if prev is None else f",delta={prev - ready:+.2f}s"))
        bench.add(f"fig9/real-loader/{name}", ready, derived)
        assert ready <= (prev if prev is not None else ready) + 1e-9, \
            f"ablation step {name} regressed the measured timeline"
        prev = ready


def run(bench: Bench, model: str = "llama2-13b"):
    apps = [a for a in APPLICATIONS if a.model == model]
    prev = None
    for name, kw in STEPS:
        # two concurrent cold starts of different models on a small cluster
        # to exercise NIC contention (paper's production motivation)
        insts = make_instances(apps[:1], 2, slo_scale=100.0)
        sim = ServerlessSim(testbed_i(), profiles(), insts, **kw)
        reqs = burst(insts[0], 1) + [
            r for r in burst(insts[1], 1)]
        for i, r in enumerate(reqs):
            r.req_id = i
        sim.submit(reqs)
        sim.run(until=600)
        ttft = max(r.ttft for r in reqs)
        derived = "" if prev is None else f"delta={prev-ttft:+.2f}s"
        bench.add(f"fig9/{model}/{name}", ttft, derived)
        prev = ttft


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-loader", action="store_true",
                    help="execute the ablation through the on-disk "
                         "ModelStore + StreamedStageLoader and cross-check "
                         "measured vs analytic spans (<=5%%)")
    args = ap.parse_args()
    b = Bench()
    if args.real_loader:
        run_real(b)
    else:
        run(b)
    b.emit()


if __name__ == "__main__":
    main()
