"""Fig. 9 — incremental technique breakdown: vLLM baseline, +Prefetch,
+Stream, +Overlap, +Parallel (the paper's ablation, under 2-way NIC
contention where overlap matters most)."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.core.coldstart import OverlapFlags
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import burst, make_instances

STEPS = [
    ("vllm", dict(system="vllm")),
    ("+prefetch", dict(system="hydra", force_s=1,
                       flags=OverlapFlags(True, False, False),
                       consolidate=False)),
    ("+stream", dict(system="hydra", force_s=1,
                     flags=OverlapFlags(True, True, False),
                     consolidate=False)),
    ("+overlap", dict(system="hydra", force_s=1,
                      flags=OverlapFlags(True, True, True),
                      consolidate=False)),
    ("+parallel", dict(system="hydra", force_s=4,
                       flags=OverlapFlags(True, True, True),
                       consolidate=False)),
]


def run(bench: Bench, model: str = "llama2-13b"):
    apps = [a for a in APPLICATIONS if a.model == model]
    prev = None
    for name, kw in STEPS:
        # two concurrent cold starts of different models on a small cluster
        # to exercise NIC contention (paper's production motivation)
        insts = make_instances(apps[:1], 2, slo_scale=100.0)
        sim = ServerlessSim(testbed_i(), profiles(), insts, **kw)
        reqs = burst(insts[0], 1) + [
            r for r in burst(insts[1], 1)]
        for i, r in enumerate(reqs):
            r.req_id = i
        sim.submit(reqs)
        sim.run(until=600)
        ttft = max(r.ttft for r in reqs)
        derived = "" if prev is None else f"delta={prev-ttft:+.2f}s"
        bench.add(f"fig9/{model}/{name}", ttft, derived)
        prev = ttft


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
