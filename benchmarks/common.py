"""Shared benchmark harness: the paper's two testbeds, model profiles, and
CSV emission in the ``name,us_per_call,derived`` contract."""

from __future__ import annotations

import sys
import time

from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO
from repro.workloads.applications import (APPLICATIONS, WARM, kv_bytes_for,
                                          timings_for)


def testbed_i():
    """(i) 4x A10 (1 GPU, 188 GB host) + 4x V100 (4 GPUs) @ 16 Gbps."""
    servers = [ServerSpec(f"a10-{i}", 16 * Gbps, 12e9, 24 * GB, 1)
               for i in range(4)]
    servers += [ServerSpec(f"v100-{i}", 16 * Gbps, 12e9, 32 * GB, 4)
                for i in range(4)]
    return servers


def testbed_ii():
    """(ii) 2x A10 servers (4 GPUs, 64 Gbps) + 4x V100 (4 GPUs, 16 Gbps)."""
    servers = [ServerSpec(f"a10-{i}", 64 * Gbps, 12e9, 24 * GB, 4)
               for i in range(2)]
    servers += [ServerSpec(f"v100-{i}", 16 * Gbps, 12e9, 32 * GB, 4)
                for i in range(4)]
    return servers


def profiles():
    return {name: ModelProfile(name, w.size_bytes, timings_for(name),
                               SLO(7.5, 0.2),
                               kv_bytes_per_token=kv_bytes_for(name))
            for name, w in WARM.items()}


class Bench:
    """Collects (name, us_per_call, derived) rows and prints CSV."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, seconds: float, derived: str = ""):
        self.rows.append((name, seconds * 1e6, derived))

    def timeit(self, name: str, fn, repeat: int = 3, derived: str = ""):
        best = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        self.add(name, best, derived)
        return best

    def emit(self, file=None):
        file = file or sys.stdout
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}", file=file)
