"""Fig. 13 — total tokens generated over time for one cold request with and
without scale-down consolidation (Llama2-13B, 512 in / 512 out)."""

from __future__ import annotations

import dataclasses

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import ModelInstance, Request


def one_request(consolidate: bool):
    inst = ModelInstance("fig13#0", "chatbot-13b", "llama2-13b",
                         slo_ttft=1e9, slo_tpot=1e9,
                         mean_prompt=512, mean_output=512)
    sim = ServerlessSim(testbed_i(), profiles(), [inst], system="hydra",
                        force_s=4, consolidate=consolidate)
    req = Request(0, inst.name, inst.app, 0.0, 512, 512, 1e9, 1e9)
    sim.submit([req])
    sim.run(until=1200)
    return req


def run(bench: Bench):
    base = one_request(consolidate=False)
    cons = one_request(consolidate=True)
    e2e_base = base.completion - base.arrival
    e2e_cons = cons.completion - cons.arrival
    bench.add("fig13/pipeline-only/e2e", e2e_base,
              f"ttft={base.ttft:.2f}s;tpot={base.tpot*1e3:.0f}ms")
    bench.add("fig13/scale-down/e2e", e2e_cons,
              f"ttft={cons.ttft:.2f}s;tpot={cons.tpot*1e3:.0f}ms;"
              f"speedup={e2e_base/e2e_cons:.2f}x")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
