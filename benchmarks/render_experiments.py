"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSONL records.

    PYTHONPATH=src python -m benchmarks.render_experiments
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

MOVE_HINT = {
    ("compute", "train"): "fewer recompute passes (selective remat) and "
    "causal-skip attention would cut compute directly",
    ("compute", "prefill"): "causal-skip blocked attention halves the "
    "dominant score-matmul FLOPs",
    ("memory", "decode"): "KV-cache layout/quantization (int8 KV) or larger "
    "decode batch amortizes the weight+cache stream",
    ("memory", "train"): "activation re-layout to cut copies",
    ("memory", "prefill"): "fuse cache writes",
    ("collective", "train"): "overlap gradient reduce-scatter with backward "
    "compute; bf16 grads already halve volume",
    ("collective", "decode"): "move the per-layer TP all-reduce to "
    "reduce-scatter on the residual stream",
    ("collective", "prefill"): "sequence-parallel boundary collectives "
    "already minimal; overlap with compute",
}


def load(mesh: str, policy: str = "baseline"):
    path = os.path.join(DRYRUN_DIR, f"{mesh}_{policy}.jsonl")
    rows = {}
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("ok"):
                rows[(r["arch"], r["shape"])] = r
    return rows


def shape_kind(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def render_roofline(rows) -> str:
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MODEL_FLOPS | useful ratio | roofline frac | "
           "mem/dev (GiB) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(rows.items()):
        out.append(
            f"| {arch} | {shape} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['model_flops']:.3g} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{r['peak_mem_gb']:.1f} |")
    return "\n".join(out)


def render_dryrun(rows, mesh) -> str:
    out = [f"### Mesh {mesh}",
           "",
           "| arch | shape | args bytes/dev | temp bytes/dev | "
           "collective bytes/dev (parsed HLO) | compile (s) |",
           "|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(rows.items()):
        coll = sum(r["coll_bytes"].values())
        out.append(
            f"| {arch} | {shape} | {r.get('arg_bytes', 0):,} | "
            f"{r.get('temp_bytes', 0):,} | {coll:,} | "
            f"{r.get('compile_s', 0):.1f} |")
    return "\n".join(out)


def render_hints(rows) -> str:
    out = []
    for (arch, shape), r in sorted(rows.items()):
        hint = MOVE_HINT.get((r["dominant"], shape_kind(shape)), "")
        out.append(f"- **{arch} × {shape}** ({r['dominant']}-bound): {hint}")
    return "\n".join(out)


def main():
    single = load("16x16")
    multi = load("2x16x16")
    print("## §Dry-run\n")
    print(f"Single-pod cells: {len(single)}/32 OK; "
          f"multi-pod cells: {len(multi)}/32 OK\n")
    print(render_dryrun(single, "16x16 (256 chips)"))
    print()
    print(render_dryrun(multi, "2x16x16 (512 chips)"))
    print("\n## §Roofline (single-pod 16x16, baseline policy)\n")
    print(render_roofline(single))
    print("\n### What moves the dominant term\n")
    print(render_hints(single))


if __name__ == "__main__":
    main()
