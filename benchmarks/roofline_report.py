"""§Roofline report — reads the dry-run JSONL records and emits the
per-(arch x shape x mesh) roofline table rows as bench CSV, plus the
analytic KV-bytes-per-token rows (full production geometry, per KV pool
storage dtype) that gate the quantized-KV claims without needing dry-run
records."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Bench

from repro.configs import get_config
from repro.roofline.analytic import kv_token_bytes

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

KV_ARCHS = ("granite-3-8b", "qwen1.5-32b")
KV_DTYPES = (None, "float16", "int8")        # None = legacy bf16 roofline


def kv_bytes_rows(bench: Bench):
    """Analytic KV bytes/token across ALL attention layers at full
    config geometry for each pool storage dtype — the decode KV-stream
    term of the roofline, and the gate for 'int8 pages halve decode
    bytes/token'. Runs with or without dry-run records."""
    for arch in KV_ARCHS:
        cfg = get_config(arch)
        base = kv_token_bytes(cfg, "float16")
        for kd in KV_DTYPES:
            label = "bf16-legacy" if kd is None else kd
            b = kv_token_bytes(cfg, kd)
            bench.add(f"roofline/kv-bytes-per-token/{arch}/{label}",
                      0.0, f"bytes={b};vs_fp16={b / base:.3f}")
        assert kv_token_bytes(cfg, "int8") / base <= 0.6, \
            f"int8 must (near-)halve KV bytes/token at {arch} geometry"


def run(bench: Bench):
    kv_bytes_rows(bench)
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.jsonl")))
    if not files:
        bench.add("roofline/no-dryrun-records", 0.0,
                  "run: python -m repro.launch.dryrun --all")
        return
    seen = {}
    for path in files:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if not r.get("ok"):
                    continue
                seen[(r["arch"], r["shape"], r["mesh"], r["policy"])] = r
    for (arch, shape, mesh, policy), r in sorted(seen.items()):
        bench.add(
            f"roofline/{mesh}/{policy}/{arch}/{shape}",
            r["compute_s"],
            f"dom={r['dominant']};mem_s={r['memory_s']:.4f};"
            f"coll_s={r['collective_s']:.4f};"
            f"frac={r['roofline_fraction']:.3f};"
            f"mem_gb={r['peak_mem_gb']:.1f}")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
