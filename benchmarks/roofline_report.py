"""§Roofline report — reads the dry-run JSONL records and emits the
per-(arch x shape x mesh) roofline table rows as bench CSV."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Bench

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(bench: Bench):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.jsonl")))
    if not files:
        bench.add("roofline/no-dryrun-records", 0.0,
                  "run: python -m repro.launch.dryrun --all")
        return
    seen = {}
    for path in files:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if not r.get("ok"):
                    continue
                seen[(r["arch"], r["shape"], r["mesh"], r["policy"])] = r
    for (arch, shape, mesh, policy), r in sorted(seen.items()):
        bench.add(
            f"roofline/{mesh}/{policy}/{arch}/{shape}",
            r["compute_s"],
            f"dom={r['dominant']};mem_s={r['memory_s']:.4f};"
            f"coll_s={r['collective_s']:.4f};"
            f"frac={r['roofline_fraction']:.3f};"
            f"mem_gb={r['peak_mem_gb']:.1f}")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
