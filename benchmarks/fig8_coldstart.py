"""Fig. 8 — cold-start TTFT per system per model (single request, idle
cluster). Also covers Table-1-style derived ratios vs serverless vLLM."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.generator import ModelInstance, burst


def single_cold_ttft(system: str, model: str, **kw) -> float:
    inst = ModelInstance(f"{model}#0", "chatbot", model,
                         slo_ttft=1e6, slo_tpot=1e6,   # no SLO pressure
                         mean_prompt=315, mean_output=240)
    sim = ServerlessSim(testbed_i(), profiles(), [inst], system=system, **kw)
    reqs = burst(inst, 1)
    sim.submit(reqs)
    sim.run(until=600)
    return reqs[0].ttft


def run(bench: Bench):
    for model in ("llama2-7b", "llama2-13b", "opt-6.7b"):
        base = single_cold_ttft("vllm", model)
        bench.add(f"fig8/{model}/serverless-vllm", base)
        sllm = single_cold_ttft("serverlessllm", model)
        bench.add(f"fig8/{model}/serverlessllm", sllm,
                  f"speedup={base/sllm:.2f}x")
        h1 = single_cold_ttft("hydra", model, force_s=1)
        bench.add(f"fig8/{model}/hydra-s1", h1, f"speedup={base/h1:.2f}x")
        h4 = single_cold_ttft("hydra", model, force_s=4)
        bench.add(f"fig8/{model}/hydra-s4", h4, f"speedup={base/h4:.2f}x")


def main():
    b = Bench()
    run(b)
    b.emit()


if __name__ == "__main__":
    main()
