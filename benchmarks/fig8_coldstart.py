"""Fig. 8 — cold-start TTFT per system per model (single request, idle
cluster). Also covers Table-1-style derived ratios vs serverless vLLM."""

from __future__ import annotations

from benchmarks.common import Bench, profiles, testbed_i
from repro.serving.simulation import ServerlessSim
from repro.workloads.generator import ModelInstance, burst


def single_cold_ttft(system: str, model: str, **kw) -> float:
    inst = ModelInstance(f"{model}#0", "chatbot", model,
                         slo_ttft=1e6, slo_tpot=1e6,   # no SLO pressure
                         mean_prompt=315, mean_output=240)
    sim = ServerlessSim(testbed_i(), profiles(), [inst], system=system, **kw)
    reqs = burst(inst, 1)
    sim.submit(reqs)
    sim.run(until=600)
    return reqs[0].ttft


def run_real(bench: Bench, tol: float = 0.05):
    """--real-loader: cold-start the tiny smoke model through the real
    on-disk ModelStore at s in {1, 4} and report the measured per-stage
    readiness next to worker_timeline's analytic prediction (matched
    bandwidths; the Fig. 8 point is that s-way stage fetches shrink the
    dominant fetch span ~s-fold)."""
    import tempfile

    import jax

    from repro.configs import get_config, smoke_variant
    from repro.models import build_model
    from repro.store import ModelStore, assert_within, crosscheck_stages
    from repro.workloads.applications import timings_for

    import dataclasses

    cfg = dataclasses.replace(smoke_variant(get_config("granite-3-8b")),
                              n_layers=4)   # 4 periods -> s up to 4
    m = build_model(cfg)
    store = ModelStore.save(tempfile.mkdtemp(prefix="fig8-store-"),
                            m, m.init(jax.random.PRNGKey(0)))
    t = timings_for("llama2-7b")
    nic = store.total_bytes / 10.0            # full-model fetch ~10 s
    ready = {}
    for s in (1, 4):
        checks = crosscheck_stages(store, s, timings=t,
                                   nic_bytes_per_s=nic,
                                   load_bytes_per_s=nic * 4)
        worst = assert_within(checks, tol)
        ready[s] = max(c.measured.timeline.ready for c in checks)
        analytic = max(c.analytic.ready for c in checks)
        for c in checks:
            bench.add(f"fig8/real-loader/s{s}/stage{c.stage}",
                      c.measured.timeline.ready,
                      f"analytic={c.analytic.ready:.2f}s,"
                      f"err={c.max_err * 100:.2f}%")
        bench.add(f"fig8/real-loader/s{s}", ready[s],
                  f"analytic={analytic:.2f}s,err={worst * 100:.2f}%")
    bench.add("fig8/real-loader/s4-vs-s1", ready[4],
              f"speedup={ready[1] / ready[4]:.2f}x")
    assert ready[4] < ready[1], "s=4 stage fetches must beat s=1"


def run(bench: Bench):
    for model in ("llama2-7b", "llama2-13b", "opt-6.7b"):
        base = single_cold_ttft("vllm", model)
        bench.add(f"fig8/{model}/serverless-vllm", base)
        sllm = single_cold_ttft("serverlessllm", model)
        bench.add(f"fig8/{model}/serverlessllm", sllm,
                  f"speedup={base/sllm:.2f}x")
        h1 = single_cold_ttft("hydra", model, force_s=1)
        bench.add(f"fig8/{model}/hydra-s1", h1, f"speedup={base/h1:.2f}x")
        h4 = single_cold_ttft("hydra", model, force_s=4)
        bench.add(f"fig8/{model}/hydra-s4", h4, f"speedup={base/h4:.2f}x")


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-loader", action="store_true",
                    help="cold-start a tiny model through the on-disk "
                         "ModelStore and cross-check measured vs analytic "
                         "stage spans (<=5%%)")
    args = ap.parse_args()
    b = Bench()
    if args.real_loader:
        run_real(b)
    else:
        run(b)
    b.emit()


if __name__ == "__main__":
    main()
