import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and dump roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k [--multi-pod] [--policy kvseq]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results (memory analysis, cost analysis, collective bytes) are appended as
JSON lines under experiments/dryrun/.
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import (SHAPES, applicable_shapes, get_config,  # noqa: E402
                           list_configs)
from repro.distributed.sharding import use_mesh                     # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.launch.specs import make_cell, rules_for                 # noqa: E402
from repro.roofline import analysis                                 # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             policy: str = "baseline", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    from repro.kernels import ops as kops
    kops.set_attention_mode("causal_skip" if "skip" in policy
                            else "masked_full")
    kops.set_decode_mode("append" if "kvapp" in policy else "scatter")
    t0 = time.time()

    if "ppipe" in policy and shape.kind == "prefill":
        from repro.distributed import pp_spmd
        from repro.launch.mesh import make_pp_mesh
        assert pp_spmd.supports(cfg), f"{arch}: PP-SPMD unsupported"
        mesh = make_pp_mesh(4)
        mesh_name = "4x4x16(pp)"
        fn, args, in_sh, out_sh, donate = pp_spmd.make_pp_prefill(
            cfg, mesh, shape.global_batch, shape.seq_len)
    elif "manual" in policy and shape.kind == "prefill":
        from repro.distributed import manual_tp
        assert manual_tp.supports(cfg), f"{arch}: manual TP unsupported"
        fn, args, in_sh, out_sh, donate = manual_tp.make_manual_prefill(
            cfg, mesh, shape.global_batch, shape.seq_len)
    else:
        fn, args, in_sh, out_sh, donate = make_cell(cfg, shape, mesh,
                                                    policy=policy)
    with use_mesh(mesh, rules_for(shape, policy, cfg)):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    hlo = compiled.as_text()
    roof = analysis.analyze(arch, shape, mesh_name, chips, cost, mem, hlo,
                            cfg, policy=policy)
    rec = roof.row()
    rec.update({
        "policy": policy,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
        "out_bytes": getattr(mem, "output_size_in_bytes", None),
        "gen_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        "ok": True,
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {mesh_name} "
              f"(policy={policy}): OK "
              f"compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"dominant={roof.dominant} "
              f"mem/dev={rec['peak_mem_gb']:.2f}GiB "
              f"compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['arg_bytes']} "
              f"temps={rec['temp_bytes']} out={rec['out_bytes']}")
    return rec


def cells(multi_pod: bool):
    for arch, cfg in sorted(list_configs().items()):
        if arch in ("llama2-7b", "llama2-13b", "opt-6.7b"):
            continue                      # paper models: bench-only
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--policy", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already recorded")
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(OUT_DIR)
    os.makedirs(out_dir, exist_ok=True)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    out_path = os.path.join(out_dir, f"{mesh_name}_{args.policy}.jsonl")

    done = set()
    if args.resume and os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"]))

    todo = ([(args.arch, args.shape)] if not args.all
            else list(cells(args.multi_pod)))
    failures = []
    with open(out_path, "a") as f:
        for arch, shape in todo:
            if (arch, shape) in done:
                print(f"[dryrun] skip {arch} x {shape} (done)")
                continue
            try:
                rec = run_cell(arch, shape, args.multi_pod, args.policy)
            except (ValueError, TypeError, KeyError, NotImplementedError,
                    RuntimeError, MemoryError, OSError) as e:
                # a cell that fails to lower/compile is recorded and the
                # sweep continues; anything else (KeyboardInterrupt,
                # SystemExit, real bugs like NameError) propagates
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "policy": args.policy, "ok": False, "error": str(e)}
                failures.append((arch, shape, str(e)))
            f.write(json.dumps(rec) + "\n")
            f.flush()
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells compiled")


if __name__ == "__main__":
    main()
