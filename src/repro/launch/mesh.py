"""Production meshes. Functions (not module-level constants) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pp_mesh(n_stages: int = 4):
    """Technique-representative mesh: a pipeline axis for the paper's
    cold-start groups, within one pod."""
    return jax.make_mesh((n_stages, 256 // n_stages // 16, 16),
                         ("stage", "data", "model"))


def make_cpu_mesh():
    """Single-device mesh for tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
