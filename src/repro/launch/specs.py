"""Per-(arch x shape) input specs and sharding rules for the dry-run and
the production launchers.

Rules are the hillclimbing surface: ``rules_for(shape, policy)`` returns the
logical->physical table; policies beyond 'baseline' are recorded in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import resolve, use_mesh
from repro.models.common import param_structs
from repro.models.model import Model
from repro.training import optimizer as opt


def rules_for(shape: ShapeConfig, policy: str = "baseline",
              cfg: Optional[ModelConfig] = None) -> dict:
    """Logical-axis overrides per input shape.

    baseline: DP over batch, TP over heads/ffn/vocab/experts — the paper-
              faithful megatron-style layout; + sequence parallelism on the
              residual stream for train/prefill; + FSDP for archs whose
              TP=16 weight slice exceeds one chip's HBM.
    """
    rules: dict = {}
    if cfg is not None and cfg.fsdp:
        # weights' d_model dim additionally sharded over 'data'; activations
        # are unaffected ('batch' claims 'data' first in resolve())
        rules["embed"] = "data"
    if (shape.kind in ("train", "prefill") and shape.seq_len % 16 == 0
            and policy != "nosp"):
        # Megatron-style sequence parallelism on the residual stream: saved
        # (B,S,d) layer-boundary activations shard over 'model'
        rules["act_seq"] = "model"
    if shape.kind in ("decode", "prefill"):
        # KV-head counts (4/8/12/40) don't divide TP=16, so the KV cache
        # shards its *sequence* dim over 'model' (flash-decoding style SP).
        if shape.global_batch == 1:
            # long-context decode: batch unshardable; spread the cache over
            # every axis we have
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
            rules["kv_heads"] = None
        else:
            rules["kv_seq"] = "model"
            rules["kv_heads"] = None
    return rules


def batch_sharding_spec(shape: ShapeConfig) -> P:
    if shape.kind == "decode" and shape.global_batch == 1:
        return P()
    return P(("pod", "data"))


def _fix1(mesh, s: P) -> NamedSharding:
    """Drop axes absent from this mesh (e.g. 'pod' on single-pod)."""
    parts = []
    for part in s:
        if part is None:
            parts.append(None)
            continue
        ax = (part,) if isinstance(part, str) else tuple(part)
        ax = tuple(a for a in ax if a in mesh.axis_names)
        parts.append(ax if len(ax) > 1 else (ax[0] if ax else None))
    return NamedSharding(mesh, P(*parts))


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: _fix1(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
              policy: str = "baseline", remat: str = "full"):
    """Build (fn, arg_structs, in_shardings, out_shardings) for one cell."""
    model = Model(cfg)
    rules = rules_for(shape, policy, cfg)
    # VLM: the assigned seq_len covers the full decoder context; the image
    # prefix occupies the first n_image_tokens of it
    text_seq = shape.seq_len - (cfg.n_image_tokens
                                if cfg.family == "vlm" else 0)
    with use_mesh(mesh, rules):
        pspecs = model.specs()
        p_sh = _named(mesh, pspecs)
        bspec = batch_sharding_spec(shape)
        dtype = jnp.dtype(cfg.dtype)

        if shape.kind == "train":
            from repro.training.train_step import make_train_step
            step = make_train_step(model, remat=remat)
            batch_structs = model.input_structs(shape.global_batch,
                                                text_seq)
            batch_sh = jax.tree.map(
                lambda s: _fix1(mesh, bspec if s.ndim >= 2 else P()),
                batch_structs)
            ostructs = opt.state_structs(model.structs())
            o_specs = opt.state_specs(model.defs, zero1=True)
            o_sh = _named(mesh, o_specs)
            args = (model.structs(), ostructs, batch_structs)
            in_sh = (p_sh, o_sh, batch_sh)
            out_sh = (p_sh, o_sh, None)
            return step, args, in_sh, out_sh, (0, 1)   # donate params+opt

        if shape.kind == "prefill":
            def prefill_step(params, batch):
                logits, cache = model.prefill(params, batch, shape.seq_len,
                                              remat="none")
                return logits, cache

            batch_structs = model.input_structs(shape.global_batch,
                                                text_seq)
            batch_sh = jax.tree.map(lambda s: _fix1(mesh, bspec),
                                    batch_structs)
            cache_sh = _named(mesh, jax.tree.map(
                resolve, model.cache_axes(),
                is_leaf=lambda x: isinstance(x, tuple) and
                all(isinstance(i, (str, type(None))) for i in x)))
            logits_sh = _fix1(mesh, P(("pod", "data")))
            args = (model.structs(), batch_structs)
            return (prefill_step, args, (p_sh, batch_sh),
                    (logits_sh, cache_sh), ())

        # decode: one new token against a cache of seq_len
        cache_structs = model.init_cache(shape.global_batch, shape.seq_len,
                                         as_structs=True)
        cache_sh = _named(mesh, jax.tree.map(
            resolve, model.cache_axes(),
            is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(i, (str, type(None))) for i in x)))

        def serve_step(params, cache, tokens, positions):
            return model.decode_step(params, cache, tokens, positions)

        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_sh = _fix1(mesh, bspec)
        logits_sh = _fix1(
            mesh, P() if shape.global_batch == 1 else P(("pod", "data")))
        args = (model.structs(), cache_structs, tok, pos)
        in_sh = (p_sh, cache_sh, tok_sh, tok_sh)
        out_sh = (logits_sh, cache_sh)
        return serve_step, args, in_sh, out_sh, (1,)   # donate the cache
