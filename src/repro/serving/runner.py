"""Plan execution for the serving engine: the ModelRunner.

The runner is the compute half of the scheduler/runner split
(serving/scheduler.py): it owns the ``StageWorker`` pipeline and turns a
``ScheduleBatch``'s assignments into forwards — prefill chunks for one
slot, one batched decode over the decode set — returning logits. It
holds **no queue or policy state**; everything it knows about a request
is the slot / tokens / positions the engine hands it.

It also owns the paged layout's batched block table: a ``(B,
table_width)`` int32 array kept **incrementally** current — rows are
updated on allocate / extend / free / preempt instead of being rebuilt
from the BlockManager every step (the pre-split engine rebuilt and
re-uploaded the whole table per forward). The device-side copy is cached
too and only re-uploaded after a row actually changes, so steady-state
decode steps (no block boundary crossed) reuse the same device array.
Idle slots point at the null page so their (unused) writes never land in
a live page; for decode, half-prefilled slots are masked out the same
way — they take no part in the decode batch and their dummy writes must
not land in live (possibly shared) pages.
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.kvcache import KVInvariantError
from repro.serving.worker import StageWorker


class ModelRunner:
    def __init__(self, cfg: ModelConfig, stage_params: Sequence[dict],
                 max_batch: int, max_seq: int, *, paged: bool,
                 n_blocks: int, block_size: int, kv_dtype=None):
        self.cfg = cfg
        self.paged = paged
        self.max_batch = max_batch
        self.kv_dtype = kv_dtype
        self._attn_only = (all(m == "attn" for m in cfg.mixer_pattern)
                           and not cfg.is_encdec)
        # one extra trash page: idle slots' block-table rows point here so
        # their (unused) decode writes never land in a live page; the
        # ragged path also routes pad-token writes to it
        self._null_page = n_blocks
        self._table_width = max_seq // block_size + 1
        n = len(stage_params)
        self.workers = [StageWorker(cfg, p, n, i, max_batch, max_seq,
                                    paged=paged, n_pages=n_blocks + 1,
                                    page_size=block_size, kv_dtype=kv_dtype)
                        for i, p in enumerate(stage_params)]
        self._bt = np.full((max_batch, self._table_width), self._null_page,
                           np.int32)
        # correctness tracer (analysis/sanitizer.py); None in production
        self.tracer = None
        self._bt_dev = None             # cached device copy, None = dirty
        # masked decode-view cache: (frozen skip set, device array) — a
        # mixed step with the same half-prefilled slots and unchanged rows
        # reuses it instead of re-masking + re-uploading every forward
        self._masked_dev = (None, None)

    # --------------------------------------------------- block-table rows
    def set_row(self, slot: int, blocks: Sequence[int]):
        """(Re)write one slot's block-table row: called on allocate and
        whenever extend crosses a block boundary."""
        if not self.paged:
            return
        if self.tracer is not None:
            self.tracer.on_set_row(slot, list(blocks))
        row = self._bt[slot]
        row[:] = self._null_page
        row[:len(blocks)] = blocks
        self._bt_dev = None
        self._masked_dev = (None, None)

    def clear_row(self, slot: int):
        """Point a vacated slot (finish / preempt) back at the null page."""
        if not self.paged:
            return
        if self.tracer is not None:
            self.tracer.on_clear_row(slot)
        self._bt[slot] = self._null_page
        self._bt_dev = None
        self._masked_dev = (None, None)

    def rebuild_rows(self, requests: Iterable, tables: dict):
        """Full rebuild from BlockManager state — only needed when a
        consolidated engine adopts another engine's residents."""
        if not self.paged:
            return
        self._bt[:] = self._null_page
        for r in requests:
            blocks = tables[r.rid].blocks
            if self.tracer is not None:
                self.tracer.on_set_row(r.slot, list(blocks))
            self._bt[r.slot, :len(blocks)] = blocks
        self._bt_dev = None
        self._masked_dev = (None, None)

    def _tables(self) -> jnp.ndarray:
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self._bt)
        return self._bt_dev

    # ------------------------------------------------------------ compute
    def prefill(self, slot: int, tokens: Sequence[int], start: int, n: int,
                prefix_embeds=None):
        """One prefill forward over rows [start, start+n) of a request's
        chain, writing KV through the slot's block-table row (paged) or
        the slot's contiguous strip. Returns the pipeline output — the
        last stage's logits at the final row."""
        if self.paged and self._attn_only and prefix_embeds is None:
            # satellite path: run the chunk as a one-segment ragged batch.
            # History length is *dynamic* there (per-token positions drive
            # the mask), so compiles are bounded by the power-of-two token
            # buckets instead of one executable per (chunk_len, hist_len).
            h = self.forward_batch([(slot, list(tokens), start)])
            return h[0][None, None]
        if self.tracer is not None:
            self.tracer.on_prefill(slot, start, n)
        prefix = None
        if prefix_embeds is not None:
            prefix = jnp.asarray(prefix_embeds)[None]
        h = jnp.asarray([list(tokens)], jnp.int32)
        positions = jnp.arange(start, start + n, dtype=jnp.int32)[None]
        bt = None
        if self.paged:
            bt = self._tables()[slot:slot + 1]
        for w in self.workers:
            h = w.prefill_slot(h, slot, positions, prefix_embeds=prefix,
                               block_tables=bt, hist_len=start)
        return h

    def decode(self, reqs: Sequence, skip_slots: Sequence[int] = ()):
        """One batched decode over ``reqs`` (each contributes its last
        generated token at its next cache position). ``skip_slots`` are
        live-but-not-decoding slots (half-prefilled residents) whose
        table rows are masked to the null page for this forward."""
        if self.tracer is not None:
            self.tracer.on_decode([(r.slot, r.pos_next) for r in reqs],
                                  list(skip_slots))
        tokens = np.zeros((self.max_batch, 1), np.int32)
        positions = np.zeros((self.max_batch, 1), np.int32)
        for r in reqs:
            tokens[r.slot, 0] = r.generated[-1]
            positions[r.slot, 0] = r.pos_next
        bt = None
        if self.paged:
            if skip_slots:
                key = frozenset(skip_slots)
                if self._masked_dev[0] != key:
                    masked = self._bt.copy()
                    masked[list(skip_slots)] = self._null_page
                    self._masked_dev = (key, jnp.asarray(masked))
                bt = self._masked_dev[1]
            else:
                bt = self._tables()
        h = jnp.asarray(tokens)
        pos = jnp.asarray(positions)
        for w in self.workers:
            h = w.decode(h, pos, block_tables=bt)
        return h

    _TILE_Q = 8     # ragged span alignment (kernels/ragged_attention.py)

    def forward_batch(self, segments: Sequence):
        """ONE fused launch over a mixed ragged batch. ``segments`` is a
        list of (slot, tokens, pos0) — prefill chunks (len > 1, pos0 =
        rows already in the pool) and decode rows (len 1) freely mixed,
        at most one segment per slot. Tokens are flattened into a single
        ragged axis; each segment's span is tile-aligned (pad tokens get
        pos = -1 → masked, writes routed to the trash page) and the total
        is bucketed to a power of two so the jit cache stays O(log
        max_tokens). Returns (max_batch, V) logits — row i is segment
        i's last real token's logits."""
        if not (self.paged and self._attn_only):
            raise KVInvariantError(
                "forward_batch requires the paged attention-only layout")
        if not 0 < len(segments) <= self.max_batch:
            raise KVInvariantError(
                f"{len(segments)} segments for max_batch={self.max_batch}")
        if self.tracer is not None:
            self.tracer.on_forward_batch(
                [(s, len(tk), p0) for s, tk, p0 in segments])
        tq = self._TILE_Q
        toks: List[int] = []
        poss: List[int] = []
        rows: List[int] = []
        out_idx = [0] * self.max_batch
        for i, (slot, tokens, pos0) in enumerate(segments):
            n = len(tokens)
            na = -(-n // tq) * tq
            out_idx[i] = len(toks) + n - 1
            toks.extend(int(t) for t in tokens)
            toks.extend([0] * (na - n))
            poss.extend(range(pos0, pos0 + n))
            poss.extend([-1] * (na - n))
            # pad rows inside a segment's aligned span keep its slot so
            # `row` stays constant per tile (the kernel's layout contract)
            rows.extend([slot] * na)
        t = len(toks)
        tb = tq
        while tb < t:
            tb *= 2
        toks.extend([0] * (tb - t))
        poss.extend([-1] * (tb - t))
        rows.extend([0] * (tb - t))
        x = jnp.asarray([toks], jnp.int32)
        pos = jnp.asarray([poss], jnp.int32)
        row = jnp.asarray(rows, jnp.int32)
        valid = jnp.asarray([p >= 0 for p in poss])
        oi = jnp.asarray(out_idx, jnp.int32)
        bt = self._tables()
        h = x
        for w in self.workers:
            h = w.forward_ragged(h, pos, row, valid, bt, oi)
        return h[0]

    # -------------------------------------------------------- maintenance
    def copy_pages(self, src: int, dst: int):
        """Apply a prefix-cache copy-on-write to every stage's pools."""
        for w in self.workers:
            w.copy_pages(src, dst)

    def read_pages(self, blk: int):
        """One block's KV across the whole model, as a pipeline-shape
        independent payload: ordered (cache_slot_name, k, v) triples whose
        page arrays are concatenated over the stages along the period
        axis — a payload read from a 2-stage engine writes back into its
        consolidated 1-stage successor (or any same-model replica)
        unchanged. Quantized pools append a 4th element per entry: a dict
        of the scale/zero leaves, concatenated the same way."""
        out = []
        for name, sub in self.workers[0].cache.items():
            if "k_pages" not in sub:
                continue
            parts = [w.read_page(name, blk) for w in self.workers]
            k = np.concatenate([p["k_pages"] for p in parts], axis=0)
            v = np.concatenate([p["v_pages"] for p in parts], axis=0)
            extra = [l for l in parts[0] if l not in ("k_pages", "v_pages")]
            if extra:
                aux = {l: np.concatenate([p[l] for p in parts], axis=0)
                       for l in extra}
                out.append((name, k, v, aux))
            else:
                out.append((name, k, v))
        return out

    def write_pages(self, blk: int, payload):
        """Scatter a spilled block's payload (see ``read_pages``) back
        into the stage pools, splitting the period axis by each stage's
        share."""
        for entry in payload:
            name, k, v = entry[0], entry[1], entry[2]
            aux = entry[3] if len(entry) > 3 else {}
            off = 0
            for w in self.workers:
                p = w.cache[name]["k_pages"].shape[0]
                extras = {l: a[off:off + p] for l, a in aux.items()} or None
                w.write_page(name, blk, k[off:off + p], v[off:off + p],
                             extras=extras)
                off += p
            if off != k.shape[0]:
                raise KVInvariantError(
                    f"payload periods {k.shape[0]} != pipeline periods {off}")

    def clear_slot(self, slot: int):
        """Zero a vacated slot's recurrent state on every stage."""
        for w in self.workers:
            w.clear_slot(slot)

    def retire(self):
        """Drop caches and params so a retired engine's stale runner
        fails fast instead of writing into pools it no longer owns."""
        for w in self.workers:
            w.retire()
        self.workers = []
