"""Plan execution for the serving engine: the ModelRunner.

The runner is the compute half of the scheduler/runner split
(serving/scheduler.py): it owns the ``StageWorker`` pipeline and turns a
``ScheduleBatch``'s assignments into forwards — prefill chunks for one
slot, one batched decode over the decode set — returning logits. It
holds **no queue or policy state**; everything it knows about a request
is the slot / tokens / positions the engine hands it.

It also owns the paged layout's batched block table: a ``(B,
table_width)`` int32 array kept **incrementally** current — rows are
updated on allocate / extend / free / preempt instead of being rebuilt
from the BlockManager every step (the pre-split engine rebuilt and
re-uploaded the whole table per forward). The device-side copy is cached
too and only re-uploaded after a row actually changes, so steady-state
decode steps (no block boundary crossed) reuse the same device array.
Idle slots point at the null page so their (unused) writes never land in
a live page; for decode, half-prefilled slots are masked out the same
way — they take no part in the decode batch and their dummy writes must
not land in live (possibly shared) pages.
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.worker import StageWorker


class ModelRunner:
    def __init__(self, cfg: ModelConfig, stage_params: Sequence[dict],
                 max_batch: int, max_seq: int, *, paged: bool,
                 n_blocks: int, block_size: int):
        self.cfg = cfg
        self.paged = paged
        self.max_batch = max_batch
        # one extra trash page: idle slots' block-table rows point here so
        # their (unused) decode writes never land in a live page
        self._null_page = n_blocks
        self._table_width = max_seq // block_size + 1
        n = len(stage_params)
        self.workers = [StageWorker(cfg, p, n, i, max_batch, max_seq,
                                    paged=paged, n_pages=n_blocks + 1,
                                    page_size=block_size)
                        for i, p in enumerate(stage_params)]
        self._bt = np.full((max_batch, self._table_width), self._null_page,
                           np.int32)
        self._bt_dev = None             # cached device copy, None = dirty
        # masked decode-view cache: (frozen skip set, device array) — a
        # mixed step with the same half-prefilled slots and unchanged rows
        # reuses it instead of re-masking + re-uploading every forward
        self._masked_dev = (None, None)

    # --------------------------------------------------- block-table rows
    def set_row(self, slot: int, blocks: Sequence[int]):
        """(Re)write one slot's block-table row: called on allocate and
        whenever extend crosses a block boundary."""
        if not self.paged:
            return
        row = self._bt[slot]
        row[:] = self._null_page
        row[:len(blocks)] = blocks
        self._bt_dev = None
        self._masked_dev = (None, None)

    def clear_row(self, slot: int):
        """Point a vacated slot (finish / preempt) back at the null page."""
        if not self.paged:
            return
        self._bt[slot] = self._null_page
        self._bt_dev = None
        self._masked_dev = (None, None)

    def rebuild_rows(self, requests: Iterable, tables: dict):
        """Full rebuild from BlockManager state — only needed when a
        consolidated engine adopts another engine's residents."""
        if not self.paged:
            return
        self._bt[:] = self._null_page
        for r in requests:
            blocks = tables[r.rid].blocks
            self._bt[r.slot, :len(blocks)] = blocks
        self._bt_dev = None
        self._masked_dev = (None, None)

    def _tables(self) -> jnp.ndarray:
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self._bt)
        return self._bt_dev

    # ------------------------------------------------------------ compute
    def prefill(self, slot: int, tokens: Sequence[int], start: int, n: int,
                prefix_embeds=None):
        """One prefill forward over rows [start, start+n) of a request's
        chain, writing KV through the slot's block-table row (paged) or
        the slot's contiguous strip. Returns the pipeline output — the
        last stage's logits at the final row."""
        prefix = None
        if prefix_embeds is not None:
            prefix = jnp.asarray(prefix_embeds)[None]
        h = jnp.asarray([list(tokens)], jnp.int32)
        positions = jnp.arange(start, start + n, dtype=jnp.int32)[None]
        bt = None
        if self.paged:
            bt = self._tables()[slot:slot + 1]
        for w in self.workers:
            h = w.prefill_slot(h, slot, positions, prefix_embeds=prefix,
                               block_tables=bt, hist_len=start)
        return h

    def decode(self, reqs: Sequence, skip_slots: Sequence[int] = ()):
        """One batched decode over ``reqs`` (each contributes its last
        generated token at its next cache position). ``skip_slots`` are
        live-but-not-decoding slots (half-prefilled residents) whose
        table rows are masked to the null page for this forward."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        positions = np.zeros((self.max_batch, 1), np.int32)
        for r in reqs:
            tokens[r.slot, 0] = r.generated[-1]
            positions[r.slot, 0] = r.pos_next
        bt = None
        if self.paged:
            if skip_slots:
                key = frozenset(skip_slots)
                if self._masked_dev[0] != key:
                    masked = self._bt.copy()
                    masked[list(skip_slots)] = self._null_page
                    self._masked_dev = (key, jnp.asarray(masked))
                bt = self._masked_dev[1]
            else:
                bt = self._tables()
        h = jnp.asarray(tokens)
        pos = jnp.asarray(positions)
        for w in self.workers:
            h = w.decode(h, pos, block_tables=bt)
        return h

    # -------------------------------------------------------- maintenance
    def copy_pages(self, src: int, dst: int):
        """Apply a prefix-cache copy-on-write to every stage's pools."""
        for w in self.workers:
            w.copy_pages(src, dst)

    def read_pages(self, blk: int):
        """One block's KV across the whole model, as a pipeline-shape
        independent payload: ordered (cache_slot_name, k, v) triples whose
        page arrays are concatenated over the stages along the period
        axis — a payload read from a 2-stage engine writes back into its
        consolidated 1-stage successor (or any same-model replica)
        unchanged."""
        out = []
        for name, sub in self.workers[0].cache.items():
            if "k_pages" not in sub:
                continue
            ks, vs = [], []
            for w in self.workers:
                k, v = w.read_page(name, blk)
                ks.append(k)
                vs.append(v)
            out.append((name, np.concatenate(ks, axis=0),
                        np.concatenate(vs, axis=0)))
        return out

    def write_pages(self, blk: int, payload):
        """Scatter a spilled block's payload (see ``read_pages``) back
        into the stage pools, splitting the period axis by each stage's
        share."""
        for name, k, v in payload:
            off = 0
            for w in self.workers:
                p = w.cache[name]["k_pages"].shape[0]
                w.write_page(name, blk, k[off:off + p], v[off:off + p])
                off += p
            assert off == k.shape[0], \
                f"payload periods {k.shape[0]} != pipeline periods {off}"

    def clear_slot(self, slot: int):
        """Zero a vacated slot's recurrent state on every stage."""
        for w in self.workers:
            w.clear_slot(slot)

    def retire(self):
        """Drop caches and params so a retired engine's stale runner
        fails fast instead of writing into pools it no longer owns."""
        for w in self.workers:
            w.retire()
        self.workers = []
