"""Stable serving endpoints (§6.2) and the serverless frontend.

HydraServe's client-facing abstraction is the *serving endpoint*: pipeline
groups consolidate and scale behind it, clients never see the swap. A
``ServingEndpoint`` is that stable handle — it owns the backing
``Engine``(s), proxies the request-lifecycle API (serving/api.py), and
performs consolidation / scale-up *in place*: the handle the caller holds
keeps working, in-flight requests continue bit-exactly, and the retired
source engine raises on use instead of silently corrupting block tables
it no longer owns.

``ServerlessFrontend`` glues the control plane to the data plane: it
registers model profiles with the ``CentralController``, and on a cold
start runs Alg. 1 (``plan_cold_start``), *streams* each stage's parameter
slice out of the deployment's ``ModelStore`` (repro/store/) with the
``StreamedStageLoader``, and hands back a live endpoint whose
``cold_start_timeline`` carries the measured per-stage spans. ``deploy``
without a ``store_dir`` keeps the old in-memory behaviour as a
``ModelStore.from_params`` tier — same bytes, same engine outputs, but
the load path is the real one either way. Consolidation's full-model
fill-in (``full_params``) fetches through the store too.
"""

from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Union

from repro.configs.base import ModelConfig
from repro.core.coldstart import OverlapFlags
from repro.core.controller import CentralController
from repro.core.types import ColdStartScheme, ModelProfile, ServerSpec
from repro.models import build_model
from repro.serving.api import SamplingParams, StepOutput, TokenEvent
from repro.serving.engine import Engine, GenRequest
from repro.store.loader import (ColdStartReport, StageLoadRecord,
                                StreamedStageLoader)
from repro.store.store import FetchFlow, FetchSchedule, ModelStore


class ServingEndpoint:
    """Stable handle over a (possibly re-forming) engine. All serving
    traffic goes through the endpoint; ``consolidate``/``scale_up`` swap
    the backing engine without invalidating the handle."""

    def __init__(self, engine: Engine,
                 scheme: Optional[ColdStartScheme] = None,
                 cold_start_timeline: Optional[ColdStartReport] = None):
        self._engine = engine
        self.scheme = scheme              # Alg.1 plan that built us, if any
        # measured per-stage cold-start spans (store-backed cold starts)
        self.cold_start_timeline = cold_start_timeline
        # measured KV-migration transfer of the last consolidation, if the
        # frontend drove it (ServerlessFrontend.consolidate)
        self.last_migration_flow: Optional[FetchFlow] = None

    # -------------------------------------------------------- delegation
    @property
    def engine(self) -> Engine:
        """The live backing engine (raw-engine escape hatch)."""
        return self._engine

    @property
    def cfg(self) -> ModelConfig:
        return self._engine.cfg

    @property
    def paged(self) -> bool:
        return self._engine.paged

    @property
    def policy(self):
        """The live engine's ``SchedulingPolicy`` (survives swaps)."""
        return self._engine.policy

    @property
    def n_stages(self) -> int:
        return len(self._engine.workers)

    @property
    def finished(self) -> List[GenRequest]:
        return self._engine.finished

    @property
    def last_migration_bytes(self) -> Optional[int]:
        return self._engine.last_migration_bytes

    def active(self) -> List[GenRequest]:
        return self._engine.active()

    def has_work(self) -> bool:
        """True while any request is resident, waiting, or preempted —
        use this (not ``active() or queue``) to drive a step loop."""
        return self._engine.has_work()

    def stats(self) -> dict:
        """Cheap saturation snapshot of the live engine (waiting depth,
        free slots/blocks, preemptions...) — the KV-aware router's
        overflow input; survives engine swaps."""
        return self._engine.stats()

    def submit(self, prompt: Sequence[int],
               params: Union[SamplingParams, int, None] = None, *,
               max_new: Optional[int] = None,
               prefix_embeds=None) -> GenRequest:
        return self._engine.submit(prompt, params, max_new=max_new,
                                   prefix_embeds=prefix_embeds)

    def step(self) -> StepOutput:
        return self._engine.step()

    def run(self, max_steps: int = 10_000) -> List[StepOutput]:
        return self._engine.run(max_steps)

    def generate(self, prompt: Sequence[int],
                 params: Union[SamplingParams, int, None] = None, *,
                 prefix_embeds=None,
                 max_steps: int = 10_000) -> Iterator[TokenEvent]:
        return self._engine.generate(prompt, params,
                                     prefix_embeds=prefix_embeds,
                                     max_steps=max_steps)

    # ------------------------------------------------- elastic membership
    def consolidate(self, full_params: dict) -> "ServingEndpoint":
        """§6.2 scale-down behind the handle: gather KV/state onto one
        standalone worker, swap it in, retire the pipeline-group engine.
        In-flight requests (and ``last_migration_bytes``) carry over, and
        so do the scheduling policy and the waiting/preempted pools — a
        consolidation changes the endpoint's capacity, not its scheduling
        behaviour."""
        src = self._engine
        self._engine = src.consolidated(full_params)
        src.retire()
        return self

    def scale_up(self, full_params: dict) -> List["ServingEndpoint"]:
        """§6.2 scale-up: each stage becomes a standalone replica. This
        handle keeps the consolidated engine (in-flight requests continue);
        the fresh replicas come back as new endpoints. Returns all
        endpoints, this one first."""
        src = self._engine
        engines = src.scale_up(full_params)
        src.retire()
        self._engine = engines[0]
        return [self] + [ServingEndpoint(e) for e in engines[1:]]


@dataclass
class _Deployment:
    cfg: ModelConfig
    model: Optional[object]               # repro.models.Model; None for a
    store: ModelStore                     # cold deploy from an on-disk store
    profile: ModelProfile


class PendingColdStart:
    """A cold start whose stage fetch flows are admitted on the shared
    schedule but not yet resolved. ``finish()`` streams the stage
    parameters and builds the live endpoint; everything begun before the
    first ``finish`` contends on the simulated NICs."""

    def __init__(self, name: str, dep: "_Deployment", scheme,
                 flags: OverlapFlags, pending, engine_kw: dict):
        self.name = name
        self.scheme = scheme
        self._dep = dep
        self._flags = flags
        self._pending = pending
        self._engine_kw = engine_kw

    @property
    def n_stages(self) -> int:
        return len(self._pending)

    def finish(self) -> ServingEndpoint:
        stage_params, records = [], []
        for p in self._pending:
            sp, rec = p.materialize()
            stage_params.append(sp)
            records.append(rec)
        report = ColdStartReport(self.name, len(records), self._flags,
                                 records)
        eng = Engine(self._dep.cfg, stage_params, **self._engine_kw)
        return ServingEndpoint(eng, scheme=self.scheme,
                               cold_start_timeline=report)


class ServerlessFrontend:
    """Control-plane glue: model registry + Alg. 1 planning + streamed
    stage loading out of the per-model ``ModelStore``, producing
    ``ServingEndpoint``s. One frontend per cluster; all its cold-start
    fetches share one ``FetchSchedule`` over the controller's Alg. 2
    contention tracker, so concurrent cold starts on a server contend."""

    def __init__(self, servers: Dict[str, ServerSpec],
                 controller: Optional[CentralController] = None,
                 **controller_kw):
        self.controller = controller or CentralController(servers,
                                                          **controller_kw)
        self.servers = self.controller.servers
        self.schedule = FetchSchedule(self.controller.tracker)
        self._deployed: Dict[str, _Deployment] = {}
        self._fid = itertools.count()
        # measured record of the last full_params store fetch (§6.2)
        self.last_full_fetch: Optional[StageLoadRecord] = None

    def deploy(self, cfg: ModelConfig, params: Optional[dict],
               profile: ModelProfile, *,
               store: Optional[ModelStore] = None,
               store_dir: Optional[str] = None) -> ModelStore:
        """'Upload' a model: register its profile with the controller and
        chunk the weights into a ``ModelStore`` the cold-start data plane
        fetches from. ``store_dir`` writes (and serves from) the on-disk
        chunk layout; an explicit ``store`` is used as-is; neither keeps
        the weights behind an in-memory ``ModelStore.from_params`` tier
        — every cold start streams through the store regardless.

        ``params=None`` is the *cold deploy* path: the model was never
        resident in this process — its bytes already live in an existing
        on-disk store (``store_dir``) or an explicit ``store``, and the
        first cold start is the first time any of them are read."""
        self.controller.register_model(profile)
        model = build_model(cfg) if params is not None else None
        if store is None:
            if params is None:
                if store_dir is None:
                    raise ValueError(
                        "cold deploy (params=None) needs an existing store: "
                        "pass store= or store_dir=")
                store = ModelStore.open(store_dir)
            elif store_dir is not None:
                store = ModelStore.save(store_dir, model, params)
            else:
                store = ModelStore.from_params(model, params)
        self._deployed[profile.name] = _Deployment(cfg, model, store,
                                                   profile)
        return store

    def store_of(self, name: str) -> ModelStore:
        return self._deployed[name].store

    def _loader(self, dep: _Deployment, flags: OverlapFlags,
                tier: Optional[str], load_bw: float) -> StreamedStageLoader:
        return StreamedStageLoader(dep.store, self.schedule,
                                   dep.profile.timings, flags,
                                   load_bytes_per_s=load_bw, tier=tier)

    def _load_bw(self, server_ids: Sequence[str]) -> float:
        known = [self.servers[s].pcie_bytes_per_s for s in server_ids
                 if s in self.servers]
        return min(known) if known else 12e9

    def begin_cold_start(self, name: str, *, now: float = 0.0,
                         free_hbm: Optional[Dict[str, int]] = None,
                         force_s: Optional[int] = None, min_stages: int = 1,
                         max_batch: int = 4, max_seq: int = 128,
                         block_size: int = 16,
                         paged: Optional[bool] = None,
                         prefix_cache: bool = False,
                         prefill_chunk: Optional[int] = None,
                         policy: str = "fcfs",
                         kv_tier=None,
                         flags: OverlapFlags = OverlapFlags.all(),
                         tier: Optional[str] = None,
                         fallback_tier: Optional[str] = None,
                         prefer: Optional[Sequence[str]] = None
                         ) -> "PendingColdStart":
        """Phase 1 of a cold start: plan the Alg. 1 scheme and *admit*
        every stage's fetch into the shared schedule without resolving
        any of them. A fleet launching several models in one tick begins
        them all first, then ``finish()``es each — flows landing on the
        same server then contend per Alg. 2, exactly like the stages of
        a single group already do.

        ``prefer`` biases scheme selection toward those servers (the
        fleet passes the model's proactive placements). When ``tier`` is
        None and the scheme lands on a server this model is pre-seeded
        on, the placement's tier is used automatically — a proactively
        distributed model fetches from its fast tier; an *unseeded*
        scheme falls back to ``fallback_tier`` (the fleet passes the
        store's authoritative/slowest tier; None keeps the store's
        default fastest tier, the single-model behaviour)."""
        dep = self._deployed[name]
        scheme = self.controller.plan_cold_start(name, free_hbm, now,
                                                 force_s=force_s,
                                                 prefer=prefer)
        n_stages = min(max(scheme.s, min_stages), dep.cfg.n_periods)
        if n_stages == scheme.s:
            servers = list(scheme.servers)
        else:                       # min_stages overrode the plan's degree
            pool = scheme.servers or tuple(self.servers)
            servers = [pool[i % len(pool)] for i in range(n_stages)]
        if tier is None:
            placed = {self.controller.placement_tier(name, sid)
                      for sid in servers} - {None}
            for t in sorted(placed):
                if dep.store.has_tier(t):
                    tier = t
                    break
            else:
                tier = fallback_tier
        deadline = self.controller.fetch_deadline(name, scheme, now)
        loader = self._loader(dep, flags, tier, self._load_bw(servers))
        worker_ids = [f"{name}/f{next(self._fid)}-s{i}"
                      for i in range(n_stages)]
        pending = [loader.admit_stage(n_stages, i, server_id=servers[i],
                                      worker_id=worker_ids[i], now=now,
                                      deadline=deadline)
                   for i in range(n_stages)]
        engine_kw = dict(max_batch=max_batch, max_seq=max_seq,
                         block_size=block_size, paged=paged,
                         prefix_cache=prefix_cache,
                         prefill_chunk=prefill_chunk, policy=policy,
                         kv_tier=kv_tier)
        return PendingColdStart(name, dep, scheme, flags, pending,
                                engine_kw)

    def cold_start(self, name: str, **kw) -> ServingEndpoint:
        """Alg. 1 cold start, executed: pick a pipeline scheme, admit
        every stage's fetch into the shared schedule (stages landing on
        the same server contend per Alg. 2), stream each stage's
        parameters out of the store in manifest order, and return a live
        endpoint whose ``cold_start_timeline`` is the *measured* per-stage
        ``WorkerTimeline`` report under ``flags``.
        ``prefix_cache``/``prefill_chunk``/``policy`` pass through to the
        engine (the first two need the paged layout) and survive
        consolidation. (``begin_cold_start`` + ``finish`` split the same
        operation for concurrent fleet launches.)"""
        return self.begin_cold_start(name, **kw).finish()

    def full_params(self, name: str, *, now: float = 0.0,
                    server_id: Optional[str] = None,
                    tier: Optional[str] = None) -> dict:
        """The un-sliced weights, fetched through the store (the paper's
        warm-pool / object-store fill-in that consolidation's standalone
        worker performs). The measured record of the last such fetch is
        kept on ``last_full_fetch``."""
        dep = self._deployed[name]
        sid = server_id or next(iter(self.servers), "local")
        # the consolidating worker is already warm: no container/lib/cuda
        # stubs, just the measured fetch + load legs
        warm = dataclasses.replace(dep.profile.timings,
                                   t_cc=0.0, t_l=0.0, t_cu=0.0)
        loader = StreamedStageLoader(dep.store, self.schedule, warm,
                                     OverlapFlags.all(),
                                     load_bytes_per_s=self._load_bw([sid]),
                                     tier=tier)
        params, record = loader.load_stage(
            1, 0, server_id=sid, worker_id=f"{name}/full{next(self._fid)}",
            now=now)
        self.last_full_fetch = record
        return params

    def consolidate(self, endpoint: ServingEndpoint, name: str, *,
                    now: float = 0.0,
                    tier: Optional[str] = None) -> ServingEndpoint:
        """§6.2 scale-down, data plane included: fetch the full weights
        through the store onto the surviving worker's server, swap the
        consolidated engine in behind the endpoint handle, then account
        the measured KV-migration transfer (``last_migration_bytes`` —
        the exact bytes the paged gather moved) as a real flow on that
        server's NIC (``endpoint.last_migration_flow``)."""
        sid = endpoint.scheme.servers[0] if (
            endpoint.scheme and endpoint.scheme.servers) \
            else next(iter(self.servers), "local")
        params = self.full_params(name, now=now, server_id=sid, tier=tier)
        endpoint.consolidate(params)
        moved = endpoint.last_migration_bytes
        if moved:
            endpoint.last_migration_flow = self.schedule.transfer(
                sid, f"{name}/kvmig{next(self._fid)}", moved,
                now=max(now, self.last_full_fetch.timeline.ready))
        return endpoint
