"""Stable serving endpoints (§6.2) and the serverless frontend.

HydraServe's client-facing abstraction is the *serving endpoint*: pipeline
groups consolidate and scale behind it, clients never see the swap. A
``ServingEndpoint`` is that stable handle — it owns the backing
``Engine``(s), proxies the request-lifecycle API (serving/api.py), and
performs consolidation / scale-up *in place*: the handle the caller holds
keeps working, in-flight requests continue bit-exactly, and the retired
source engine raises on use instead of silently corrupting block tables
it no longer owns.

``ServerlessFrontend`` glues the control plane to the data plane: it
registers model profiles with the ``CentralController``, and on a cold
start runs Alg. 1 (``plan_cold_start``), slices stage parameters for the
chosen pipeline degree, and hands back a live endpoint.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Union

from repro.configs.base import ModelConfig
from repro.core.controller import CentralController
from repro.core.types import ColdStartScheme, ModelProfile, ServerSpec
from repro.models import build_model
from repro.serving.api import SamplingParams, StepOutput, TokenEvent
from repro.serving.engine import Engine, GenRequest


class ServingEndpoint:
    """Stable handle over a (possibly re-forming) engine. All serving
    traffic goes through the endpoint; ``consolidate``/``scale_up`` swap
    the backing engine without invalidating the handle."""

    def __init__(self, engine: Engine,
                 scheme: Optional[ColdStartScheme] = None):
        self._engine = engine
        self.scheme = scheme              # Alg.1 plan that built us, if any

    # -------------------------------------------------------- delegation
    @property
    def engine(self) -> Engine:
        """The live backing engine (raw-engine escape hatch)."""
        return self._engine

    @property
    def cfg(self) -> ModelConfig:
        return self._engine.cfg

    @property
    def paged(self) -> bool:
        return self._engine.paged

    @property
    def policy(self):
        """The live engine's ``SchedulingPolicy`` (survives swaps)."""
        return self._engine.policy

    @property
    def n_stages(self) -> int:
        return len(self._engine.workers)

    @property
    def finished(self) -> List[GenRequest]:
        return self._engine.finished

    @property
    def last_migration_bytes(self) -> Optional[int]:
        return self._engine.last_migration_bytes

    def active(self) -> List[GenRequest]:
        return self._engine.active()

    def has_work(self) -> bool:
        """True while any request is resident, waiting, or preempted —
        use this (not ``active() or queue``) to drive a step loop."""
        return self._engine.has_work()

    def submit(self, prompt: Sequence[int],
               params: Union[SamplingParams, int, None] = None, *,
               max_new: Optional[int] = None,
               prefix_embeds=None) -> GenRequest:
        return self._engine.submit(prompt, params, max_new=max_new,
                                   prefix_embeds=prefix_embeds)

    def step(self) -> StepOutput:
        return self._engine.step()

    def run(self, max_steps: int = 10_000) -> List[StepOutput]:
        return self._engine.run(max_steps)

    def generate(self, prompt: Sequence[int],
                 params: Union[SamplingParams, int, None] = None, *,
                 prefix_embeds=None,
                 max_steps: int = 10_000) -> Iterator[TokenEvent]:
        return self._engine.generate(prompt, params,
                                     prefix_embeds=prefix_embeds,
                                     max_steps=max_steps)

    # ------------------------------------------------- elastic membership
    def consolidate(self, full_params: dict) -> "ServingEndpoint":
        """§6.2 scale-down behind the handle: gather KV/state onto one
        standalone worker, swap it in, retire the pipeline-group engine.
        In-flight requests (and ``last_migration_bytes``) carry over, and
        so do the scheduling policy and the waiting/preempted pools — a
        consolidation changes the endpoint's capacity, not its scheduling
        behaviour."""
        src = self._engine
        self._engine = src.consolidated(full_params)
        src.retire()
        return self

    def scale_up(self, full_params: dict) -> List["ServingEndpoint"]:
        """§6.2 scale-up: each stage becomes a standalone replica. This
        handle keeps the consolidated engine (in-flight requests continue);
        the fresh replicas come back as new endpoints. Returns all
        endpoints, this one first."""
        src = self._engine
        engines = src.scale_up(full_params)
        src.retire()
        self._engine = engines[0]
        return [self] + [ServingEndpoint(e) for e in engines[1:]]


@dataclass
class _Deployment:
    cfg: ModelConfig
    model: object                         # repro.models.Model
    params: dict


class ServerlessFrontend:
    """Control-plane glue: model registry + Alg. 1 planning + stage-param
    slicing, producing ``ServingEndpoint``s. One frontend per cluster."""

    def __init__(self, servers: Dict[str, ServerSpec],
                 controller: Optional[CentralController] = None,
                 **controller_kw):
        self.controller = controller or CentralController(servers,
                                                          **controller_kw)
        self._deployed: Dict[str, _Deployment] = {}

    def deploy(self, cfg: ModelConfig, params: dict,
               profile: ModelProfile) -> None:
        """'Upload' a model: register its profile with the controller and
        keep the weights ready for stage slicing on cold start."""
        self.controller.register_model(profile)
        self._deployed[profile.name] = _Deployment(cfg, build_model(cfg),
                                                   params)

    def cold_start(self, name: str, *, now: float = 0.0,
                   free_hbm: Optional[Dict[str, int]] = None,
                   force_s: Optional[int] = None, min_stages: int = 1,
                   max_batch: int = 4, max_seq: int = 128,
                   paged: Optional[bool] = None,
                   prefix_cache: bool = False,
                   prefill_chunk: Optional[int] = None,
                   policy: str = "fcfs") -> ServingEndpoint:
        """Alg. 1 cold start: pick a pipeline scheme, slice each stage's
        parameters, and return a live endpoint (its ``scheme`` attribute
        records the plan). ``prefix_cache``/``prefill_chunk``/``policy``
        pass through to the engine (the first two need the paged layout)
        and survive consolidation — a pipeline group that consolidates
        mid-flight keeps scheduling by the same rules."""
        dep = self._deployed[name]
        scheme = self.controller.plan_cold_start(name, free_hbm, now,
                                                 force_s=force_s)
        n_stages = min(max(scheme.s, min_stages), dep.cfg.n_periods)
        stage_params = [dep.model.slice_stage_params(dep.params, n_stages, i)
                        for i in range(n_stages)]
        eng = Engine(dep.cfg, stage_params, max_batch=max_batch,
                     max_seq=max_seq, paged=paged,
                     prefix_cache=prefix_cache, prefill_chunk=prefill_chunk,
                     policy=policy)
        return ServingEndpoint(eng, scheme=scheme)

    def full_params(self, name: str) -> dict:
        """The un-sliced weights — what consolidation's standalone worker
        loads (in the paper: fetched from the warm pool / object store)."""
        return self._deployed[name].params
