"""Paged-KV block bookkeeping: a ref-counted, content-addressed page pool.

In paged mode (Engine(paged=True)) the BlockManager IS the serving memory
system: the block ids it hands out index the workers' shared page pools,
prefill/decode write through them, admission reserves against
``free_blocks``/``blocks_needed`` (Engine._can_admit), and §6.2
KV-migration gathers exactly ``blocks_of`` the in-flight requests
("query the cache block manager to obtain the blocks used by existing
requests"). In the slot-contiguous layout it remains the paged
*accounting* twin of the contiguous caches and quotes migration byte
costs.

With ``prefix_cache=True`` the pool is additionally *content-addressed*
(vLLM-style automatic prefix caching):

  * every **full** block whose KV has actually been computed is
    registered under a token-chain hash (sha256 over the block's tokens
    chained with the previous block's hash, so a block id stands for a
    whole prefix, not a bag of tokens);
  * ``allocate`` matches a new request's prompt against the index and
    shares the longest cached prefix — shared blocks just gain a
    reference, only the suffix needs fresh blocks (and fresh compute);
  * a fully-cached prompt still recomputes its last token (the engine
    needs logits to sample from), so the last matched block is
    **copied-on-write**: the match keeps a private copy and the shared
    page is never written through;
  * ``free`` keeps registered blocks around at refcount zero as an LRU
    cache instead of returning them to the free list; ``allocate`` /
    ``extend`` evict those cold blocks LRU-first when the free list runs
    dry, so cached prefixes never cause admission to defer.

Registration is **engine-driven** (``commit``): blocks enter the index
only once their KV has been written by a prefill chunk or decode step —
a half-prefilled request never exposes garbage pages to other requests.

``blocks_of`` / ``migration_bytes`` are dedup-aware: a block shared by
several in-flight requests is reported (and shipped by §6.2
consolidation) exactly once.

**Notifications** (``commit_hooks`` / ``evict_hooks``): every index
mutation is observable. A commit hook fires when a chain hash enters the
index (engine commit or host-tier restore); an evict hook fires when one
leaves it (LRU eviction in ``_take_block``, consolidation's
``drop_unreferenced_cache``) — *before* the block id is handed out for
reuse, so a listener can still read the page content (the engine's
HBM→host KV spill) or drop the hash from an external residency index
(the router's per-replica warm-prefix map) without ever going stale.

**Multi-tier restore** (``kv_tier``): when a lower KV tier is attached
(see repro/router/kvtier.py), ``allocate``'s prefix match does not stop
at the first HBM index miss — a chain block whose hash the tier holds is
assigned a *fresh* block, registered in the index, and queued on
``pending_restores``; the engine drains the queue
(``Engine._apply_restores``) by copying the spilled page bytes back into
the worker pools and accounting the transfer as a measured flow. A
restored block is indistinguishable from a committed one afterwards:
prefill skips it, followers share it, eviction spills it again.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class KVInvariantError(RuntimeError):
    """A KV-lifecycle invariant was violated (refcount underflow, short
    token chain, payload/pipeline mismatch, ...). Raised explicitly — not
    via ``assert`` — so ``python -O`` cannot strip the guard."""


def _chain_hash(prev: bytes, block_tokens: Sequence[int]) -> bytes:
    """Hash of a full block's token ids chained onto its prefix's hash."""
    h = hashlib.sha256(prev)
    h.update(np.asarray(list(block_tokens), np.int64).tobytes())
    return h.digest()


@dataclass
class BlockTable:
    request_id: int
    blocks: List[int] = field(default_factory=list)
    length: int = 0                  # tokens written
    tokens: Optional[List[int]] = None   # token-id chain (None: not hashable)
    cached_tokens: int = 0           # prefix tokens served from the cache
    restored_tokens: int = 0         # ...of which came from a lower KV tier
    _n_hashed: int = 0               # full blocks whose chain hash is known
    _chain: bytes = b""              # running chain hash over those blocks


class BlockManager:
    def __init__(self, n_blocks: int, block_size: int,
                 bytes_per_token: int, prefix_cache: bool = False):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.bytes_per_token = bytes_per_token
        self.prefix_cache = prefix_cache
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._ref: List[int] = [0] * n_blocks
        self.tables: Dict[int, BlockTable] = {}
        # content-addressing state (prefix_cache only)
        self._index: Dict[bytes, int] = {}       # chain hash -> block id
        self._hash_of: Dict[int, bytes] = {}     # block id -> chain hash
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref==0
        self.pending_copies: List[Tuple[int, int]] = []  # COW (src, dst)
        # index-mutation notifications: fired with (block_id, chain_hash)
        # when a hash enters / leaves the index. Evict hooks fire BEFORE
        # the block id is reused, while its page content is still intact.
        self.commit_hooks: List[Callable[[int, bytes], None]] = []
        self.evict_hooks: List[Callable[[int, bytes], None]] = []
        # lower KV tier consulted by allocate's prefix match (duck-typed:
        # needs only .has(hash)); restores queued for the engine to apply
        self.kv_tier = None
        self.pending_restores: List[Tuple[bytes, int]] = []  # (hash, dst)
        # correctness tracer (analysis/sanitizer.py). None in production —
        # every call site is guarded, so the sanitize-off path runs the
        # exact pre-instrumentation code with a single attribute test.
        self.tracer = None
        # stats
        self.cache_queries = 0
        self.cache_hit_tokens = 0
        self.evictions = 0
        self.restores = 0
        self.preempt_releases = 0

    # ------------------------------------------------------ notifications
    def _fire_commit(self, blk: int, h: bytes):
        for cb in self.commit_hooks:
            cb(blk, h)

    def _fire_evict(self, blk: int, h: bytes):
        for cb in self.evict_hooks:
            cb(blk, h)

    # ------------------------------------------------------------ alloc
    def blocks_needed(self, n_tokens: int) -> int:
        """Blocks required to hold ``n_tokens`` cache rows (ceil div)."""
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        """Convenience query for external callers. The engine's admission
        control does NOT use this — it reserves worst-case decode tails
        across all residents in one check (Engine._can_admit)."""
        return self.free_blocks >= self.blocks_needed(n_tokens)

    def _take_block(self) -> int:
        """Pop a free block, evicting the LRU cached (refcount-zero)
        block when the free list is dry. Callers check ``free_blocks``.
        The evict hooks fire before the block id is returned — the page
        content is still intact when listeners (KV spill, residency
        index) observe the eviction."""
        if self._free:
            return self._free.pop()
        blk, _ = self._cached.popitem(last=False)      # least recently used
        h = self._hash_of.pop(blk)
        if self._index.get(h) == blk:
            del self._index[h]
            self._fire_evict(blk, h)
        self.evictions += 1
        return blk

    def _ref_block(self, blk: int):
        self._ref[blk] += 1
        self._cached.pop(blk, None)   # a referenced block is not evictable

    def _unref_block(self, blk: int):
        self._ref[blk] -= 1
        if self._ref[blk] < 0:
            raise KVInvariantError(f"refcount underflow on block {blk}")
        if self._ref[blk] > 0:
            return
        h = self._hash_of.get(blk)
        if h is not None and self._index.get(h) == blk:
            self._cached[blk] = None          # keep content, LRU tail
            self._cached.move_to_end(blk)
        else:
            self._hash_of.pop(blk, None)
            self._free.append(blk)

    def allocate(self, request_id: int, n_tokens: int,
                 tokens: Optional[Sequence[int]] = None) -> BlockTable:
        """Build a block table for a request of ``n_tokens`` prompt rows.

        When the pool is content-addressed and ``tokens`` are given, the
        longest indexed prefix (full blocks only) is shared instead of
        re-allocated; ``BlockTable.cached_tokens`` tells the engine how
        many prompt tokens need no prefill compute. A fully-cached prompt
        is capped at ``n_tokens - 1`` and the block holding the final
        token is copied-on-write (see ``drain_copies``).

        With a ``kv_tier`` attached the match keeps walking past HBM
        misses: a chain block the tier holds is *restored* — it takes a
        fresh block (registered in the index immediately; the engine
        writes the spilled bytes before anything reads them) and counts
        toward ``cached_tokens`` (``BlockTable.restored_tokens`` says how
        much of that prefix rode the transfer network instead of HBM).
        """
        tr = self.tracer
        if tr is not None:
            n_pr0 = len(self.pending_restores)
            n_pc0 = len(self.pending_copies)
        t = BlockTable(request_id,
                       tokens=list(tokens) if tokens is not None else None)
        # matched chain prefix: (hash, block-or-None); None = host restore
        matched: List[Tuple[bytes, Optional[int]]] = []
        n_hbm = 0
        chain = b""
        if self.prefix_cache and tokens is not None:
            if len(tokens) < n_tokens:
                raise KVInvariantError("token chain shorter than prompt")
            self.cache_queries += 1
            h = b""
            for i in range(n_tokens // self.block_size):
                h = _chain_hash(h, tokens[i * self.block_size:
                                          (i + 1) * self.block_size])
                blk = self._index.get(h)
                if blk is None and not (self.kv_tier is not None
                                        and self.kv_tier.has(h)):
                    break
                matched.append((h, blk))
                n_hbm += blk is not None
                chain = h
        # always recompute >= 1 prompt token (the engine samples from the
        # last prefill logit), so a full-prompt hit is capped at n-1
        cached = min(len(matched) * self.block_size, max(n_tokens - 1, 0))
        # ref the HBM prefix first: a resident matched block must not be
        # LRU-evicted by the _take_block calls that follow
        for h, blk in matched:
            if blk is not None:
                self._ref_block(blk)
        cow = cached < len(matched) * self.block_size
        # fresh blocks: restored prefix blocks + the suffix, plus a
        # private copy of the COW block
        need = self.blocks_needed(n_tokens) - n_hbm + (1 if cow else 0)
        if len(self._free) + len(self._cached) < need:
            for h, blk in matched:            # roll back the prefix refs
                if blk is not None:
                    self._unref_block(blk)
            raise MemoryError("out of KV blocks")
        blocks: List[int] = []
        for h, blk in matched:
            if blk is None:                   # host-tier restore
                blk = self._take_block()
                self._ref[blk] += 1
                self._index[h] = blk
                self._hash_of[blk] = h
                self.pending_restores.append((h, blk))
                self.restores += 1
                self._fire_commit(blk, h)
                t.restored_tokens += self.block_size
            else:
                pass                          # already ref'd above
            blocks.append(blk)
        if cow:
            src = blocks.pop()                # stays pinned via its ref
            dst = self._take_block()
            self._ref[dst] += 1
            self.pending_copies.append((src, dst))
            blocks.append(dst)
        for _ in range(self.blocks_needed(n_tokens) - len(matched)):
            blk = self._take_block()
            self._ref[blk] += 1
            blocks.append(blk)
        t.blocks = blocks
        t.length = n_tokens
        t.cached_tokens = cached
        t._n_hashed = len(matched)            # chain covers the COW block too
        t._chain = chain
        self.cache_hit_tokens += cached
        self.tables[request_id] = t
        if tr is not None:
            tr.on_alloc(request_id, list(t.blocks), n_tokens,
                        shared=[b for _, b in matched if b is not None],
                        restored=list(self.pending_restores[n_pr0:]),
                        cow=list(self.pending_copies[n_pc0:]),
                        cached=cached)
        return t

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Hand the engine the pending COW ``(src, dst)`` page copies and
        release the source pins. The caller must apply the copies to the
        worker pools before the next ``allocate``/``extend`` call (which
        may evict a released source)."""
        out, self.pending_copies = self.pending_copies, []
        if self.tracer is not None:
            self.tracer.on_drain_copies(list(out))
        for src, _ in out:
            self._unref_block(src)
        return out

    def drain_restores(self) -> List[Tuple[bytes, int]]:
        """Hand the engine the pending ``(chain_hash, dst_block)`` host-
        tier restores queued by ``allocate``. The caller must write the
        spilled page bytes into the worker pools before anything reads
        the blocks — and before ``drain_copies`` is applied, since a COW
        source may itself be a restored block."""
        out, self.pending_restores = self.pending_restores, []
        return out

    def extend(self, request_id: int, n_tokens: int = 1,
               token: Optional[int] = None):
        t = self.tables[request_id]
        new_len = t.length + n_tokens
        need = self.blocks_needed(new_len) - len(t.blocks)
        if need > self.free_blocks:
            raise MemoryError("out of KV blocks")
        for _ in range(need):
            blk = self._take_block()
            self._ref[blk] += 1
            t.blocks.append(blk)
        t.length = new_len
        if self.tracer is not None:
            self.tracer.on_extend(request_id,
                                  t.blocks[-need:] if need > 0 else [],
                                  new_len)
        if t.tokens is not None:
            if token is not None and n_tokens == 1:
                t.tokens.append(token)
            else:                 # chain broken: stop hashing this table
                t.tokens = None
        return t

    def commit(self, request_id: int, n_valid: int):
        """Register full blocks whose KV is materialized through row
        ``n_valid`` in the prefix index. Engine-driven: called after each
        prefill chunk / decode write, so the index never points at pages
        that have not been computed yet."""
        if self.tracer is not None:
            self.tracer.on_commit(request_id, n_valid)
        if not self.prefix_cache:
            return
        t = self.tables.get(request_id)
        if t is None or t.tokens is None:
            return
        bs = self.block_size
        limit = min(n_valid, len(t.tokens), t.length)
        while (t._n_hashed + 1) * bs <= limit:
            i = t._n_hashed
            h = _chain_hash(t._chain, t.tokens[i * bs:(i + 1) * bs])
            blk = t.blocks[i]
            if h not in self._index:          # first writer wins; duplicate
                self._index[h] = blk          # content is simply unshared
                self._hash_of[blk] = h
                self._fire_commit(blk, h)
            t._chain = h
            t._n_hashed += 1

    def free(self, request_id: int):
        t = self.tables.pop(request_id, None)
        if self.tracer is not None:
            self.tracer.on_free(request_id, list(t.blocks) if t else None)
        if t:
            for blk in reversed(t.blocks):
                self._unref_block(blk)

    def release_for_preempt(self, request_id: int) -> int:
        """Release a *preempted* request's blocks back to the pool.

        Mechanically this unrefs the same way ``free`` does, but the
        semantics differ: the request is suspended, not finished, and it
        WILL come back. With the prefix cache on, every committed full
        block stays registered in the hash index (refcount-zero, LRU-
        evictable like any cached block), so the request's re-admission
        matches its own prefix and re-prefills only the tail that was
        never committed — or was evicted in the meantime. Preemption-by-
        recompute is therefore O(uncached tail), not O(prompt + output).
        Without the prefix cache the release is a plain free and resume
        recomputes the whole chain. Returns the number of block
        references released (0 if the request held no table).
        """
        t = self.tables.pop(request_id, None)
        if self.tracer is not None:
            self.tracer.on_release(request_id,
                                   list(t.blocks) if t else None)
        if t is None:
            return 0
        for blk in reversed(t.blocks):
            self._unref_block(blk)
        self.preempt_releases += 1
        return len(t.blocks)

    def drop_unreferenced_cache(self):
        """Forget every refcount-zero cached block (index entries and
        all). Used at §6.2 consolidation: the gather only ships blocks of
        live requests, so cold cached pages would dangle in the new
        pool."""
        for blk in self._cached:
            h = self._hash_of.pop(blk, None)
            if h is not None and self._index.get(h) == blk:
                del self._index[h]
                self._fire_evict(blk, h)
            self._free.append(blk)
        self._cached.clear()

    # ---------------------------------------------------------- queries
    def blocks_of(self, request_ids) -> List[int]:
        """Unique blocks backing these requests; a block shared by several
        requests (prefix cache) appears exactly once."""
        out: Dict[int, None] = {}
        for rid in request_ids:
            t = self.tables.get(rid)
            if t:
                for blk in t.blocks:
                    out[blk] = None
        return list(out)

    def migration_bytes(self, request_ids, n_layers: int) -> int:
        """Bytes to move when migrating these requests' KV (all layers).
        Dedup-aware: each shared block is counted once."""
        blocks = self.blocks_of(request_ids)
        return len(blocks) * self.block_size * self.bytes_per_token * n_layers

    @property
    def free_blocks(self) -> int:
        """Blocks obtainable right now: truly free plus evictable cached."""
        return len(self._free) + len(self._cached)

    @property
    def n_cached(self) -> int:
        """Refcount-zero blocks currently held by the prefix cache."""
        return len(self._cached)

    def indexed_hashes(self) -> List[bytes]:
        """Chain hashes currently registered in the prefix index — the
        ground truth an external residency index must mirror."""
        return list(self._index)

    def refcount(self, block: int) -> int:
        return self._ref[block]
