"""Paged-KV block bookkeeping (vLLM-style block manager).

In paged mode (Engine(paged=True)) the BlockManager IS the serving memory
system: the block ids it hands out index the workers' shared page pools,
prefill/decode write through them, admission consults ``can_allocate``,
and §6.2 KV-migration gathers exactly ``blocks_of`` the in-flight
requests ("query the cache block manager to obtain the blocks used by
existing requests"). In the slot-contiguous layout it remains the paged
*accounting* twin of the contiguous caches and quotes migration byte
costs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class BlockTable:
    request_id: int
    blocks: List[int] = field(default_factory=list)
    length: int = 0                  # tokens written


class BlockManager:
    def __init__(self, n_blocks: int, block_size: int,
                 bytes_per_token: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.bytes_per_token = bytes_per_token
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.tables: Dict[int, BlockTable] = {}

    # ------------------------------------------------------------ alloc
    def can_allocate(self, n_tokens: int) -> bool:
        need = -(-n_tokens // self.block_size)
        return len(self._free) >= need

    def allocate(self, request_id: int, n_tokens: int) -> BlockTable:
        need = -(-n_tokens // self.block_size)
        if len(self._free) < need:
            raise MemoryError("out of KV blocks")
        t = BlockTable(request_id, [self._free.pop() for _ in range(need)],
                       n_tokens)
        self.tables[request_id] = t
        return t

    def extend(self, request_id: int, n_tokens: int = 1):
        t = self.tables[request_id]
        new_len = t.length + n_tokens
        need = -(-new_len // self.block_size) - len(t.blocks)
        for _ in range(need):
            if not self._free:
                raise MemoryError("out of KV blocks")
            t.blocks.append(self._free.pop())
        t.length = new_len

    def free(self, request_id: int):
        t = self.tables.pop(request_id, None)
        if t:
            self._free.extend(reversed(t.blocks))

    # ---------------------------------------------------------- queries
    def blocks_of(self, request_ids) -> List[int]:
        out = []
        for rid in request_ids:
            t = self.tables.get(rid)
            if t:
                out.extend(t.blocks)
        return out

    def migration_bytes(self, request_ids, n_layers: int) -> int:
        """Bytes to move when migrating these requests' KV (all layers)."""
        blocks = self.blocks_of(request_ids)
        return len(blocks) * self.block_size * self.bytes_per_token * n_layers

    @property
    def free_blocks(self) -> int:
        return len(self._free)
