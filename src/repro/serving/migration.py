"""KV-cache migration (§6.2): gather per-stage caches to a single worker.

In the engine the gather is a period-axis concatenation of the stage caches
(paper: blocks collected with a gather primitive and 'placed at different
layers, according to which worker it comes from').

With the paged layout the gather is *block-granular*: only the pages named
by the block manager's tables for in-flight requests are shipped, and
``gather_stage_caches_with_bytes`` reports exactly the bytes moved — the
ground truth the block manager's ``migration_bytes`` estimate must match.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def gather_stage_caches_with_bytes(
        stage_caches: List[dict],
        live_blocks: Optional[Sequence[int]] = None,
        target_stage: int = 0, tracer=None) -> Tuple[dict, int]:
    """Concatenate stage cache trees along the leading (period) axis.

    Paged attention pools (``k_pages``/``v_pages`` leaves) are gathered at
    block granularity when ``live_blocks`` is given: each stage ships only
    its live pages, which land at the *same* page ids in the target pool
    (block ids are global — the engine's BlockManager is shared by every
    stage). Returns (gathered cache, KV bytes that cross the network):
    the ``target_stage`` (the worker that survives the scale-down) already
    holds its own pages, so only the other stages' live pages count.
    Non-page leaves (recurrent states, slot-contiguous KV) are
    concatenated whole and not counted.
    """
    out: dict = {}
    moved = 0
    live = None
    if live_blocks is not None:
        live = jnp.asarray(sorted(live_blocks), jnp.int32)
    for name in stage_caches[0].keys():
        sub = [c[name] for c in stage_caches]
        if live is not None and "k_pages" in sub[0]:
            merged = {}
            for leaf_name in sub[0]:
                parts = [c[leaf_name][:, live] for c in sub]
                moved += sum(int(p.nbytes) for i, p in enumerate(parts)
                             if i != target_stage)
                stacked = jnp.concatenate(parts, axis=0)
                pool = jnp.zeros((stacked.shape[0],)
                                 + sub[0][leaf_name].shape[1:],
                                 sub[0][leaf_name].dtype)
                merged[leaf_name] = pool.at[:, live].set(stacked)
            out[name] = merged
        else:
            out[name] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *sub)
    if tracer is not None:
        tracer.on_migration_gather(
            moved, list(live_blocks) if live_blocks is not None else None,
            len(stage_caches))
    return out, moved


def gather_stage_caches(stage_caches: List[dict]) -> dict:
    """Concatenate stage cache trees along the leading (period) axis
    (whole caches — the block-granular path is
    ``gather_stage_caches_with_bytes`` with ``live_blocks``)."""
    cache, _ = gather_stage_caches_with_bytes(stage_caches)
    return cache


def migration_bytes(stage_caches: List[dict], request_slots,
                    lengths) -> int:
    """Analytic estimate (slot-contiguous layout) of the bytes that cross
    the network in a scale-down migration: every stage except the target
    ships its slots' live KV/state. The paged path doesn't estimate — see
    ``gather_stage_caches_with_bytes``."""
    total = 0
    for c in stage_caches[1:]:
        for leaf in jax.tree.leaves(c):
            # per-slot share of the cache, only live slots move
            per_slot = leaf.nbytes // max(leaf.shape[1], 1)
            total += per_slot * len(request_slots)
    return total
