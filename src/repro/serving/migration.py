"""KV-cache migration (§6.2): gather per-stage caches to a single worker.

In the engine the gather is a period-axis concatenation of the stage caches
(paper: blocks collected with a gather primitive and 'placed at different
layers, according to which worker it comes from')."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp


def gather_stage_caches(stage_caches: List[dict]) -> dict:
    """Concatenate stage cache trees along the leading (period) axis."""
    out = {}
    keys = stage_caches[0].keys()
    for k in keys:
        sub = [c[k] for c in stage_caches]
        out[k] = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *sub)
    return out


def migration_bytes(stage_caches: List[dict], request_slots,
                    lengths) -> int:
    """Bytes that cross the network in a scale-down migration: every stage
    except the target ships its slots' live KV/state."""
    total = 0
    for c in stage_caches[1:]:
        for leaf in jax.tree.leaves(c):
            # per-slot share of the cache, only live slots move
            per_slot = leaf.nbytes // max(leaf.shape[1], 1)
            total += per_slot * len(request_slots)
    return total
