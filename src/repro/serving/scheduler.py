"""Policy-driven request scheduling for the serving engine.

The engine (serving/engine.py) is split into three layers:

  * **Scheduler** (this module) — owns the request queues (*waiting* /
    *running* / *preempted*) and all admission / ordering / preemption
    decisions. Every engine step it emits an explicit ``ScheduleBatch``
    plan: which requests are admitted, which prompt rows each prefill
    forward covers under the step's token budget, which residents decode,
    and which residents are preempted to make room.
  * **ModelRunner** (serving/runner.py) — purely executes a plan against
    the StageWorker pipeline and returns logits. No queue or policy
    state.
  * **Engine** — composes the two, applies sampling / finish semantics,
    and keeps the public ``submit/step/run/generate`` surface.

Scheduling is pluggable through ``SchedulingPolicy``:

  * ``fcfs`` (default) — strict submission order, head-of-line blocking,
    never preempts: **bit-exact** with the pre-split monolithic engine.
  * ``priority`` — orders admission by ``SamplingParams.priority``
    (higher first, FCFS within a level) and may preempt a lower-priority
    resident when a higher-priority request cannot be admitted.
  * ``slo`` — earliest-deadline-first over per-request TTFT/TPOT budgets
    (``SamplingParams.slo``, an :class:`repro.core.types.SLO` whose
    fields are interpreted in scheduler steps). A request with no SLO is
    background work (deadline = +inf) and is the first preemption victim.

Preemption frees the victim's slot and KV blocks
(``BlockManager.release_for_preempt``) but — with the prefix cache on —
leaves its committed full blocks registered in the hash index, so the
resume re-prefills only the uncached tail and then continues its token
stream bit-exactly (no token is ever re-emitted: the resume prefill's
logits are discarded and decode restarts from the last emitted token).
"""

from __future__ import annotations

import collections
import dataclasses
import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.api import (FinishReason, RequestMetrics, RequestOutput,
                               SamplingParams)
from repro.serving.kvcache import BlockManager


@dataclass
class GenRequest:
    """Opaque per-request handle returned by ``submit`` — callers read
    ``generated``/``done``/``finish_reason``/``metrics`` and call
    ``output()``; everything else is scheduler/engine-internal."""
    rid: int
    prompt: List[int]
    params: SamplingParams
    prefix_embeds: Optional[np.ndarray] = None
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    finish_reason: Optional[FinishReason] = None
    metrics: RequestMetrics = field(default_factory=RequestMetrics)
    prefilled: int = 0          # rows with KV computed (incl. cached)
    prefill_upto: Optional[int] = None   # rows this admission must prefill

    @property
    def max_new(self) -> int:
        return self.params.max_new

    @property
    def priority(self) -> int:
        return self.params.priority

    @property
    def prompt_total(self) -> int:
        """Prompt tokens incl. any prefix embeddings."""
        return len(self.prompt) + (0 if self.prefix_embeds is None
                                   else self.prefix_embeds.shape[0])

    @property
    def prefill_target(self) -> int:
        """Rows the current admission must materialize before decoding.
        Fresh requests prefill the whole prompt; a preempted request that
        already emitted g tokens re-prefills prompt + g - 1 rows (the
        last emitted token is re-fed by decode, not prefill)."""
        return (self.prefill_upto if self.prefill_upto is not None
                else self.prompt_total)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prefill_target

    @property
    def pos_next(self) -> int:
        """Cache position of the next token to feed."""
        return self.prompt_total + len(self.generated) - 1

    def chain(self) -> List[int]:
        """The token rows a (re-)prefill must feed: the prompt, plus —
        after a preemption — every emitted token except the last (which
        decode re-feeds). Prefix-embed rows are not part of the chain."""
        if not self.generated:
            return list(self.prompt)
        return list(self.prompt) + self.generated[:-1]

    def output(self) -> RequestOutput:
        return RequestOutput(self.rid, tuple(self.prompt),
                             tuple(self.generated), self.finish_reason,
                             dataclasses.replace(self.metrics))


# --------------------------------------------------------------- policies
class SchedulingPolicy:
    """Admission ordering + preemption victim selection. Stateless."""

    name = "base"

    def sort_key(self, req: GenRequest, step: int):
        """Admission order over waiting+preempted (ascending). Must be a
        stable total order; ties always fall back to rid."""
        raise NotImplementedError

    def victim(self, running: Sequence[GenRequest], incoming: GenRequest,
               step: int) -> Optional[GenRequest]:
        """The resident to preempt so ``incoming`` can be admitted, or
        None to keep deferring. ``running`` is pre-filtered to eligible
        victims (fully prefilled, no prefix embeddings)."""
        return None


class FCFSPolicy(SchedulingPolicy):
    """Strict submission order, never preempts — bit-exact with the
    pre-split engine's head-of-line behaviour."""

    name = "fcfs"

    def sort_key(self, req, step):
        return req.rid


class PriorityPolicy(SchedulingPolicy):
    """Higher ``SamplingParams.priority`` first (FCFS within a level);
    preempts the lowest-priority (then newest) resident when it is
    strictly less important than the incoming request."""

    name = "priority"

    def sort_key(self, req, step):
        return (-req.priority, req.rid)

    def victim(self, running, incoming, step):
        cands = [r for r in running if r.priority < incoming.priority]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority, -r.rid))


class SLOPolicy(SchedulingPolicy):
    """Earliest-deadline-first over per-request SLO budgets, in steps.

    A request that has not emitted yet is due at ``submit + slo.ttft``;
    once streaming, its next token is due at ``last_token + slo.tpot``.
    Requests without an SLO are background (deadline +inf): they are
    admitted last and preempted first. A resident is only preempted for
    an incoming request with a strictly earlier deadline."""

    name = "slo"

    @staticmethod
    def deadline(req: GenRequest) -> float:
        slo = req.params.slo
        if slo is None:
            return math.inf
        if req.metrics.last_token_step is None:
            return req.metrics.submit_step + slo.ttft
        return req.metrics.last_token_step + slo.tpot

    def sort_key(self, req, step):
        return (self.deadline(req), req.rid)

    def victim(self, running, incoming, step):
        d_in = self.deadline(incoming)
        cands = [r for r in running if self.deadline(r) > d_in]
        if not cands:
            return None
        return max(cands, key=lambda r: (self.deadline(r), r.rid))


POLICIES = {p.name: p for p in (FCFSPolicy, PriorityPolicy, SLOPolicy)}


def make_policy(policy: Union[str, SchedulingPolicy]) -> SchedulingPolicy:
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {policy!r}: "
                         f"want one of {sorted(POLICIES)} or a "
                         f"SchedulingPolicy instance") from None


# ------------------------------------------------------------------ plans
@dataclass(frozen=True)
class PrefillAssignment:
    """One prefill forward: rows [start, start+n) of ``req``'s chain."""
    req: GenRequest
    start: int
    n: int


@dataclass(frozen=True)
class ScheduleBatch:
    """One explicit scheduling decision, executed by the ModelRunner:
    requests newly admitted (blocks + slot already assigned), the prefill
    forwards to run (residents first in rid order, then admissions in
    policy order), the residents preempted to make room (with the slot
    each vacated), and the decode set (slot order). The engine may ask
    the scheduler for several batches within one step — a request that
    finishes at prefill frees its slot for a same-step admission — and
    the decode set of the final (empty-prefill) batch is authoritative."""
    admitted: Tuple[GenRequest, ...]
    prefills: Tuple[PrefillAssignment, ...]
    preempted: Tuple[Tuple[GenRequest, int], ...]
    decodes: Tuple[GenRequest, ...]

    @property
    def idle(self) -> bool:
        """No prefill work and no preemption — scheduling has converged
        for this step and ``decodes`` is final."""
        return not self.prefills and not self.preempted


# -------------------------------------------------------------- scheduler
class Scheduler:
    """Owns the waiting / running / preempted queues and emits
    ``ScheduleBatch`` plans. Mutates only scheduling state (queues, slot
    assignment, BlockManager accounting) — model compute and page-pool
    writes belong to the ModelRunner."""

    def __init__(self, block_mgr: BlockManager, max_batch: int,
                 policy: Union[str, SchedulingPolicy] = "fcfs",
                 prefix_cache: bool = False):
        self.block_mgr = block_mgr
        self.policy = make_policy(policy)
        self.prefix_cache = prefix_cache
        self.slots: List[Optional[GenRequest]] = [None] * max_batch
        self.waiting: collections.deque = collections.deque()
        self.preempted: List[GenRequest] = []
        self.n_preemptions = 0
        self._step = 0
        self._budget: float = math.inf

    # ----------------------------------------------------------- queues
    def submit(self, req: GenRequest):
        self.waiting.append(req)

    def running(self) -> List[GenRequest]:
        return [r for r in self.slots if r is not None]

    def num_queued(self) -> int:
        """Requests not holding a slot: waiting plus preempted."""
        return len(self.waiting) + len(self.preempted)

    def has_work(self) -> bool:
        return bool(self.waiting or self.preempted or self.running())

    def clear(self):
        """Drop all scheduling state (engine retirement)."""
        self.slots = [None] * len(self.slots)
        self.waiting = collections.deque()
        self.preempted = []

    def adopt(self, other: "Scheduler", block_mgr: BlockManager):
        """Take over another scheduler's request population across a
        §6.2 engine swap: slots are copied, the waiting/preempted pools
        are shared (the retired engine clears its own references)."""
        self.slots = list(other.slots)
        self.waiting = other.waiting
        self.preempted = other.preempted
        self.n_preemptions = other.n_preemptions
        self.block_mgr = block_mgr

    # --------------------------------------------------------- planning
    def begin_step(self, step: int, budget: float):
        """Arm the per-step prefill token budget before plan requests."""
        self._step = step
        self._budget = budget

    def _can_admit(self, req: GenRequest) -> bool:
        """Admission control, one authoritative BlockManager check: the
        pool must cover this request's worst-case total (prompt + decode
        tail — which subsumes the prompt itself) on top of the worst-case
        tails already reserved by in-flight requests, so ``extend`` can
        never fail mid-flight. Deliberately conservative under the prefix
        cache: a hit only means *fewer* fresh blocks are taken. A resumed
        request's worst case is unchanged — its emitted tokens count
        against the same ``prompt + max_new`` bound."""
        bm = self.block_mgr
        reserved = 0
        for r in self.running():
            held = len(bm.tables[r.rid].blocks)
            reserved += max(0, bm.blocks_needed(r.prompt_total + r.max_new)
                            - held)
        need = bm.blocks_needed(req.prompt_total + req.max_new)
        return bm.free_blocks - reserved >= need

    def _plan_prefill(self, req: GenRequest) -> PrefillAssignment:
        """Charge the budget for this request's next prefill forward.
        Monolithic engines (budget inf) take the whole remainder; chunked
        engines stop at the budget and resume next step. Prefix-embed
        prompts prefill monolithically (their embeds are not re-sliceable
        per chunk) but still charge the budget so co-resident prefills
        stay bounded."""
        remaining = req.prefill_target - req.prefilled
        n = remaining if req.prefix_embeds is not None \
            else int(min(remaining, self._budget))
        self._budget -= n
        return PrefillAssignment(req, req.prefilled, n)

    def _allocate(self, req: GenRequest):
        """Build the request's block table for (re-)admission. Fresh
        requests cover the prompt; resumed requests cover prompt + all
        emitted tokens but the last. With the prefix cache on, the chain
        is matched against the index: shared blocks need no prefill
        compute (``prefilled`` starts past them) — on a resume this is
        what turns recompute from O(prompt + output) into O(tail)."""
        target = req.prompt_total if not req.generated \
            else req.prompt_total + len(req.generated) - 1
        tokens = None
        if self.prefix_cache and req.prefix_embeds is None:
            # prefix embeddings are not part of the token chain — those
            # requests prefill from scratch
            tokens = req.chain()
        table = self.block_mgr.allocate(req.rid, target, tokens=tokens)
        req.prefill_upto = target
        req.prefilled = table.cached_tokens
        req.metrics.cached_tokens = table.cached_tokens
        req.metrics.restored_tokens = table.restored_tokens

    def _victim_pool(self) -> List[GenRequest]:
        """Residents eligible for preemption: fully prefilled (a mid-
        prefill request's chunk may already be planned this step) and
        token-addressable (prefix-embed requests cannot be re-prefilled
        from a token chain, so they are never evicted)."""
        return [r for r in self.running()
                if r.prefill_done and r.prefix_embeds is None]

    def _do_preempt(self, req: GenRequest) -> int:
        """Evict a resident: vacate its slot, release its blocks (the
        committed prefix stays in the hash index — see
        ``BlockManager.release_for_preempt``), move it to the preempted
        pool. Returns the vacated slot so the engine can clear the
        runner's table row and the worker's recurrent state."""
        slot = req.slot
        self.slots[slot] = None
        req.slot = None
        req.prefilled = 0
        req.prefill_upto = None
        req.metrics.preemptions += 1
        self.n_preemptions += 1
        self.block_mgr.release_for_preempt(req.rid)
        self.preempted.append(req)
        return slot

    def force_preempt(self, req: GenRequest) -> int:
        """Policy-independent preemption (tests, capacity changes around
        §6.2 consolidation). Same mechanics as a policy-driven eviction."""
        if req.slot is None or self.slots[req.slot] is not req:
            raise ValueError(f"request {req.rid} is not running")
        if req.prefix_embeds is not None:
            raise ValueError("prefix-embed requests cannot be preempted: "
                             "their rows are not re-prefillable from a "
                             "token chain")
        return self._do_preempt(req)

    def release(self, req: GenRequest):
        """A request finished: free its slot and blocks."""
        self.slots[req.slot] = None
        self.block_mgr.free(req.rid)

    def _head_candidate(self) -> Optional[GenRequest]:
        """The next request in policy order across waiting + preempted.
        Only the head is ever consumed per batch, so this is a single
        O(n) min, not a sort; every policy's key ties-breaks on rid, so
        the head is unique and deterministic."""
        pool = self.preempted + list(self.waiting)
        if not pool:
            return None
        return min(pool, key=lambda r: self.policy.sort_key(r, self._step))

    def schedule(self) -> ScheduleBatch:
        """Emit one ScheduleBatch under the remaining step budget.

        Plan order (preserving the pre-split engine's event order under
        FCFS): (1) half-prefilled residents continue, oldest first;
        (2) admissions in policy order — the head candidate either fits
        (slot free and blocks coverable), or the policy names preemption
        victims until it does, or planning stops (head-of-line
        deferral). The decode set is every fully-prefilled resident, in
        slot order, after admissions and preemptions have settled.

        Victim evictions apply as they are named: through the Engine
        (whose pool covers ``max_batch`` worst-case requests) evicting
        enough victims always makes the head admissible, so no eviction
        is wasted. A directly-constructed undersized pool can exhaust
        the victim pool with the head still inadmissible — the evicted
        residents then wait in ``preempted`` behind the same head until
        it fits, which is exactly the policy's strict-order contract."""
        prefills: List[PrefillAssignment] = []
        admitted: List[GenRequest] = []
        preempted: List[Tuple[GenRequest, int]] = []
        # 1. resident continuations (admission order = rid order)
        for r in sorted(self.running(), key=lambda r: r.rid):
            if self._budget <= 0:
                break
            if not r.prefill_done:
                prefills.append(self._plan_prefill(r))
        # 2. at most ONE admission per batch: the engine executes (and
        #    commits) this request's prefill before the next candidate
        #    allocates, so a same-step follower matches the leader's
        #    freshly committed prefix exactly as the pre-split engine did
        if self._budget > 0:
            req = self._head_candidate()
            if req is not None:
                admissible = self._admissible(req)
                while not admissible:
                    v = self.policy.victim(self._victim_pool(), req,
                                           self._step)
                    if v is None:
                        break             # defer until capacity frees up
                    preempted.append((v, self._do_preempt(v)))
                    admissible = self._admissible(req)
                if admissible:
                    if req in self.preempted:
                        self.preempted.remove(req)
                    else:
                        self.waiting.remove(req)
                    free = [i for i, s in enumerate(self.slots)
                            if s is None]
                    req.slot = free[0]
                    self.slots[req.slot] = req
                    self._allocate(req)
                    prefills.append(self._plan_prefill(req))
                    admitted.append(req)
        decodes = tuple(r for r in self.slots
                        if r is not None and r.prefill_done)
        return ScheduleBatch(tuple(admitted), tuple(prefills),
                             tuple(preempted), decodes)

    def _admissible(self, req: GenRequest) -> bool:
        return any(s is None for s in self.slots) and self._can_admit(req)
