"""Continuous-batching serving engine over a pipeline-parallel worker group.

Functional twin of the DES: real JAX compute (CPU-scale models), real KV
caches, real consolidation. The engine is organised around *request
lifecycles* (see serving/api.py): ``submit(prompt, SamplingParams)``
returns a request handle, every ``step()`` returns a ``StepOutput`` whose
``TokenEvent``s let callers stream, requests finish with a
``FinishReason`` (length / eos / stop_token) and carry ``RequestMetrics``
in scheduler steps.

Since the scheduler/runner split the Engine itself is thin — a
composition of two layers it drives each step:

  * ``Scheduler`` (serving/scheduler.py) owns the waiting / running /
    preempted queues and all policy decisions (admission order, prefill
    token-budget assignment, preemption victims) behind a pluggable
    ``SchedulingPolicy`` — ``fcfs`` (default, bit-exact with the
    pre-split engine), ``priority``, or ``slo`` (EDF over per-request
    TTFT/TPOT step budgets). Each step it emits explicit
    ``ScheduleBatch`` plans.
  * ``ModelRunner`` (serving/runner.py) purely executes those plans
    against the ``StageWorker`` pipeline and returns logits; it also
    keeps the paged block table incrementally current instead of
    rebuilding it every forward.

The Engine applies sampling, finish semantics, and block-accounting
side effects, and keeps the public ``submit/step/run/generate`` surface.

Under slot or block-pool pressure a non-FCFS policy *preempts* the
lowest-value resident instead of deferring the queue forever: the
victim's blocks are released (``BlockManager.release_for_preempt``) but
its committed prefix stays in the hash index, so — with the prefix cache
on — its later re-admission re-prefills only the uncached tail and the
token stream continues bit-exactly. ``preempt(req)`` forces the same
mechanics regardless of policy (tests, §6.2 capacity changes).

Most callers should not hold an Engine directly: ``ServingEndpoint``
(serving/endpoint.py) is the stable handle that swaps engines in place
across §6.2 consolidation / scale-up. ``consolidated()`` / ``scale_up()``
remain on the engine for callers that need the raw object (bit-exactness
tests), but the endpoint additionally *retires* the source engine so a
stale reference raises instead of silently corrupting the block tables it
no longer owns. The scheduling policy and the whole request population
(running, waiting, preempted) survive the swap.

KV layouts (``paged`` flag, default from ``ops.decode_mode()``):
  * contiguous — per-slot (B, Smax) caches, the seed behaviour.
  * paged — attention KV lives in a shared page pool addressed through the
    BlockManager's per-request block tables: prefill writes into allocated
    blocks, decode appends through ``extend``, admission defers requests
    when the pool can't cover them (no MemoryError mid-flight), and
    consolidation gathers exactly the live blocks.

Paged engines additionally support (attention-only decoder models):
  * ``prefix_cache=True`` — admission matches each request's token chain
    against the BlockManager's content-addressed prefix index and
    prefills only the suffix; shared blocks are reference-counted, a
    fully-cached prompt copies its last block on write, and finished or
    preempted requests' blocks stay cached (LRU-evicted before admission
    ever defers). Greedy outputs are bit-exact with the uncached engine.
  * ``prefill_chunk=N`` — prefill runs in chunks of at most N tokens per
    step, interleaved with decode (*mixed steps*): a long prompt no
    longer stalls in-flight decodes for a whole forward, so one
    request's TTFT can't starve everyone else's ITL. Half-prefilled
    requests survive §6.2 consolidation.
"""

from __future__ import annotations

import itertools
import math
from typing import Iterator, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.attention import paged_kv_token_bytes
from repro.models.model import Model
from repro.serving.api import (FinishReason, SamplingParams, StepOutput,
                               TokenEvent, sample_token)
from repro.serving.kvcache import BlockManager, KVInvariantError
from repro.serving.migration import (gather_stage_caches,
                                     gather_stage_caches_with_bytes)
from repro.serving.runner import ModelRunner
from repro.serving.scheduler import (GenRequest, PrefillAssignment,
                                     Scheduler, SchedulingPolicy)

__all__ = ["Engine", "GenRequest"]


class Engine:
    def __init__(self, cfg: ModelConfig, stage_params: Sequence[dict],
                 max_batch: int = 4, max_seq: int = 128,
                 block_size: int = 16, paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None,
                 policy: Union[str, SchedulingPolicy] = "fcfs",
                 kv_tier=None, kv_dtype=None,
                 fused: Optional[bool] = None,
                 sanitize: Optional[bool] = None):
        self.cfg = cfg
        self.model = Model(cfg)
        if paged is None:
            paged = ops.decode_mode() == "paged"
        self.paged = paged
        attn_only = (all(m == "attn" for m in cfg.mixer_pattern)
                     and not cfg.is_encdec)
        if prefix_cache or prefill_chunk is not None:
            if not paged:
                raise ValueError("prefix_cache / prefill_chunk need the "
                                 "paged KV layout (Engine(paged=True))")
            if not attn_only:
                raise ValueError(
                    "prefix_cache / prefill_chunk need an attention-only "
                    "decoder: recurrent mixer state is not block-shareable "
                    f"({cfg.name})")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if kv_dtype is not None and not paged:
            raise ValueError("kv_dtype overrides the *paged* pool storage "
                             "dtype (Engine(paged=True))")
        quantized = (kv_dtype is not None
                     and jnp.dtype(kv_dtype) == jnp.dtype(jnp.int8))
        if fused is None:
            fused = quantized
        if fused:
            if not paged:
                raise ValueError("the fused ragged step needs the paged KV "
                                 "layout (Engine(paged=True))")
            if not attn_only:
                raise ValueError(
                    "the fused ragged step needs an attention-only decoder: "
                    f"recurrent mixers can't share one token axis "
                    f"({cfg.name})")
        if quantized and not fused:
            raise ValueError("int8 KV pages are only served by the fused "
                             "ragged kernel (fused=True)")
        self.kv_dtype = kv_dtype
        self.fused = fused
        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self.max_batch = max_batch
        self.max_seq = max_seq
        # single source of truth for KV bytes/token (attention.py): with
        # kv_dtype=None this is the legacy 2*Hkv*hd*itemsize(compute dtype)
        # formula; int8 adds the per-row f32 scale/zero leaves
        kv_per_tok = paged_kv_token_bytes(cfg, kv_dtype)
        n_blocks = max_batch * (max_seq // block_size + 1)
        self.block_mgr = BlockManager(
            n_blocks=n_blocks, block_size=block_size,
            bytes_per_token=max(kv_per_tok, 1), prefix_cache=prefix_cache)
        self.scheduler = Scheduler(self.block_mgr, max_batch, policy,
                                   prefix_cache=prefix_cache)
        self.runner = ModelRunner(cfg, stage_params, max_batch, max_seq,
                                  paged=paged, n_blocks=n_blocks,
                                  block_size=block_size, kv_dtype=kv_dtype)
        self._rid = itertools.count()
        self.finished: List[GenRequest] = []
        self.steps = 0
        self.retired = False
        self.last_migration_bytes: Optional[int] = None
        self._step_prefill_tokens: int = 0
        # multi-tier KV (router/kvtier.py): LRU-evicted cached blocks
        # spill HBM -> host tier and are restored on a later prefix hit
        self.kv_tier = kv_tier
        self._spill_hook = None
        if kv_tier is not None:
            if not prefix_cache:
                raise ValueError("kv_tier needs prefix_cache=True: spilled "
                                 "blocks are content-addressed by chain "
                                 "hash")
            self.block_mgr.kv_tier = kv_tier
            self._install_spill_hook()
        # KV-lifecycle sanitizer (analysis/sanitizer.py). Explicit
        # sanitize=True demands the paged layout; env-driven enabling
        # (REPRO_SANITIZE=1) silently no-ops on non-paged engines so one
        # env var can cover a whole mixed test matrix.
        self.sanitizer = None
        if sanitize is None:
            sanitize = ops.sanitize_mode() and paged
        elif sanitize and not paged:
            raise ValueError("sanitize=True needs the paged KV layout "
                             "(Engine(paged=True))")
        if sanitize:
            from repro.analysis.sanitizer import KVSanitizer
            self.sanitizer = KVSanitizer.install(self)

    # -------------------------------------------------- multi-tier KV
    def _install_spill_hook(self):
        """Catch BlockManager evictions: read the page content (the hook
        fires before the block id is reused) and spill it to the host
        tier. The closure binds THIS engine's runner — a consolidation
        successor must rebind (``consolidated`` does)."""

        def _spill(blk: int, h: bytes):
            self.kv_tier.put(h, self.runner.read_pages(blk))

        self._spill_hook = _spill
        self.block_mgr.evict_hooks.append(_spill)

    def _remove_spill_hook(self):
        if self._spill_hook is not None:
            try:
                self.block_mgr.evict_hooks.remove(self._spill_hook)
            except ValueError:
                pass
            self._spill_hook = None

    def _apply_restores(self, admitted):
        """Write spilled page bytes back into the worker pools for every
        host-tier restore the last allocation queued, charging the
        measured transfer to the (single) admitted request. Must run
        before ``_apply_copies``: a COW source may itself be a restored
        block."""
        pending = self.block_mgr.drain_restores()
        if not pending:
            return
        if self.kv_tier is None:
            raise KVInvariantError(
                "restores pending but no kv_tier attached")
        seconds = 0.0
        for h, dst in pending:
            payload, flow = self.kv_tier.take(h)
            self.runner.write_pages(dst, payload)
            seconds += flow.seconds
        for req in admitted:              # at most one per ScheduleBatch
            req.metrics.restore_seconds += seconds

    # ------------------------------------------------------- delegation
    @property
    def policy(self) -> SchedulingPolicy:
        return self.scheduler.policy

    @property
    def workers(self):
        return self.runner.workers

    @property
    def queue(self):
        """The waiting (never-admitted) pool; preempted requests live in
        ``scheduler.preempted``."""
        return self.scheduler.waiting

    @property
    def slots(self):
        return self.scheduler.slots

    def active(self) -> List[GenRequest]:
        return self.scheduler.running()

    def has_work(self) -> bool:
        """True while any request is resident, waiting, OR preempted —
        the condition drive-your-own-step loops should poll. (Checking
        ``active() or queue`` misses the preempted pool: a preempted
        request is in neither until it is re-admitted.)"""
        return self.scheduler.has_work()

    def stats(self) -> dict:
        """Cheap saturation snapshot — the router's overflow input and a
        fleet-bench observable. Pure reads, no compute."""
        self._check_live()
        bm = self.block_mgr
        return {
            "waiting": len(self.scheduler.waiting),
            "preempted": len(self.scheduler.preempted),
            "running": len(self.active()),
            "slots": self.max_batch,
            "free_slots": sum(s is None for s in self.scheduler.slots),
            "free_blocks": bm.free_blocks,
            "total_blocks": bm.n_blocks,
            "cached_blocks": bm.n_cached,
            "preemptions": self.scheduler.n_preemptions,
            "evictions": bm.evictions,
            "restores": bm.restores,
            "steps": self.steps,
        }

    def _check_live(self):
        if self.retired:
            raise RuntimeError(
                "Engine has been retired: its ServingEndpoint swapped in a "
                "consolidated successor that owns the block tables — use "
                "the endpoint handle, not the stale engine")

    # ------------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int],
               params: Union[SamplingParams, int, None] = None, *,
               max_new: Optional[int] = None,
               prefix_embeds=None) -> GenRequest:
        self._check_live()
        if isinstance(params, int):       # legacy submit(prompt, max_new)
            params = SamplingParams(max_new=params)
        if max_new is not None:           # legacy submit(..., max_new=n)
            if params is not None:
                raise TypeError("pass either SamplingParams or max_new")
            params = SamplingParams(max_new=max_new)
        if params is None:
            params = SamplingParams()
        if prefix_embeds is not None and self.fused:
            raise ValueError("prefix_embeds (vision prefixes) are not "
                             "supported on the fused ragged step: the "
                             "flattened token axis carries token ids only")
        req = GenRequest(next(self._rid), list(prompt), params,
                         prefix_embeds)
        req.metrics.submit_step = self.steps
        if req.prompt_total + params.max_new > self.max_seq:
            raise ValueError(
                f"request needs {req.prompt_total + params.max_new} cache "
                f"slots (prompt {req.prompt_total} + max_new "
                f"{params.max_new}) > max_seq={self.max_seq}")
        self.scheduler.submit(req)
        return req

    # -------------------------------------------------------------- step
    def _finish_reason(self, req: GenRequest,
                       token: int) -> Optional[FinishReason]:
        sp = req.params
        if sp.eos_token is not None and token == sp.eos_token:
            return FinishReason.EOS
        if token in sp.stop_tokens:
            return FinishReason.STOP_TOKEN
        if len(req.generated) >= sp.max_new:
            return FinishReason.LENGTH
        return None

    def _emit(self, req: GenRequest, token: int,
              events: List[TokenEvent]) -> Optional[FinishReason]:
        req.generated.append(token)
        req.metrics.n_tokens = len(req.generated)
        req.metrics.last_token_step = self.steps
        reason = self._finish_reason(req, token)
        events.append(TokenEvent(req.rid, token, reason))
        return reason

    def _extend(self, req: GenRequest, token: int):
        """Grow the request's block table by one row (the token just fed
        or about to be fed) and mirror any new block into the runner's
        cached table row."""
        t = self.block_mgr.tables[req.rid]
        held = len(t.blocks)
        self.block_mgr.extend(req.rid, token=token)
        if len(t.blocks) != held:
            self.runner.set_row(req.slot, t.blocks)

    def _apply_copies(self):
        """Apply prefix-cache COW page copies queued by the scheduler's
        allocations to the worker pools — before anything reads (or a
        later allocation evicts) the released source pages."""
        for src, dst in self.block_mgr.drain_copies():
            self.runner.copy_pages(src, dst)

    def _exec_prefill(self, pa: PrefillAssignment,
                      events: List[TokenEvent]):
        """Run one planned prefill forward and apply its lifecycle
        effects. A fresh request that completes its prompt emits its
        first token here (and may finish outright — max_new=1, eos); a
        *resumed* request re-materializes KV for tokens it already
        emitted, so its final logits are discarded and decode simply
        restarts from the last emitted token."""
        req = pa.req
        if req.prefix_embeds is not None:
            if pa.start != 0 or pa.n != req.prompt_total:
                raise KVInvariantError(
                    "prefix_embeds prefill must cover the whole prompt in "
                    f"one chunk (got [{pa.start}, {pa.start + pa.n}) of "
                    f"{req.prompt_total})")
            tok = req.prompt
        else:
            tok = req.chain()[pa.start:pa.start + pa.n]
        h = self.runner.prefill(req.slot, tok, pa.start, pa.n,
                                prefix_embeds=req.prefix_embeds)
        req.prefilled = pa.start + pa.n
        self._step_prefill_tokens += pa.n
        self.block_mgr.commit(req.rid, req.prefilled)
        if not req.prefill_done:
            return
        if not req.generated:             # first admission: emit token 0
            req.metrics.admit_step = self.steps
            first = sample_token(h[0, 0], req.params, 0)
            reason = self._emit(req, first, events)
            self._extend(req, first)
            if reason is not None:
                self._finish(req, reason)
        else:                             # resume: decode re-feeds the tail
            self._extend(req, req.generated[-1])

    def step(self) -> StepOutput:
        """One scheduler iteration: ask the Scheduler for ScheduleBatch
        plans (half-prefilled residents resume, then policy-ordered
        admissions, preempting on pressure where the policy allows) and
        execute them until the plan is idle — a request finishing at
        prefill frees its slot for a same-step admission — then one
        batched decode over the final plan's decode set. A *mixed* step
        is one where chunked prefill and decode coexist. Returns the
        step's newly emitted token events (streaming).

        ``fused=True`` engines route through :meth:`_step_fused`: the
        same plans, but every forward of the step collapses into (at
        most) two fused ragged launches."""
        if self.fused:
            return self._step_fused()
        self._check_live()
        self.steps += 1
        events: List[TokenEvent] = []
        n_done = len(self.finished)
        self._step_prefill_tokens = 0
        sched = self.scheduler
        sched.begin_step(self.steps,
                         math.inf if self.prefill_chunk is None
                         else self.prefill_chunk)
        preempted_rids: List[int] = []
        while True:
            plan = sched.schedule()
            for req, slot in plan.preempted:
                preempted_rids.append(req.rid)
                self.runner.clear_row(slot)
                self.runner.clear_slot(slot)
            for req in plan.admitted:
                self.runner.set_row(req.slot,
                                    self.block_mgr.tables[req.rid].blocks)
            self._apply_restores(plan.admitted)
            self._apply_copies()
            for pa in plan.prefills:
                self._exec_prefill(pa, events)
            if plan.idle:
                break
        reqs = list(plan.decodes)
        if reqs:
            skip = [r.slot for r in sched.running() if not r.prefill_done]
            h = self.runner.decode(reqs, skip_slots=skip)
            greedy = None
            if any(r.params.greedy for r in reqs):
                greedy = np.asarray(jnp.argmax(h[:, 0], axis=-1))
            for r in reqs:
                if r.params.greedy:
                    nxt = int(greedy[r.slot])
                else:
                    nxt = sample_token(h[r.slot, 0], r.params,
                                       len(r.generated))
                r.metrics.decode_steps += 1
                reason = self._emit(r, nxt, events)
                # the fed token's KV is now material through pos_next + 1
                self.block_mgr.commit(
                    r.rid, r.prompt_total + len(r.generated) - 1)
                self._extend(r, nxt)
                if reason is not None:
                    self._finish(r, reason)
        return StepOutput(self.steps, tuple(events),
                          tuple(r.rid for r in self.finished[n_done:]),
                          len(self.active()), sched.num_queued(),
                          prefill_tokens=self._step_prefill_tokens,
                          preempted=tuple(preempted_rids))

    def _step_fused(self) -> StepOutput:
        """One scheduler iteration on the fused ragged path. The plan loop
        runs exactly as in :meth:`step` but *defers the compute*: prefill
        assignments only advance ``req.prefilled`` (so later plans see the
        right resume/decode sets) and queue their chunks. Then:

          * launch 1 — ONE fused ragged forward over every pending
            prefill chunk plus every request that was already decoding
            (``plan.decodes`` minus the requests still completing prefill
            this step);
          * launch 2 — the requests that *completed* prefill this step:
            fresh ones need their first token sampled (from launch 1's
            logits) before they can decode it, resumed ones re-feed their
            last emitted token.

        Block commits move after launch 1 (a same-step follower misses
        sharing a chunk prefilled this very step and recomputes it —
        streams are unchanged); emission order matches the legacy step
        exactly (prefill first-tokens in plan order, then decode tokens in
        ``plan.decodes`` order), so greedy token streams are bit-exact
        with a non-fused engine."""
        self._check_live()
        self.steps += 1
        events: List[TokenEvent] = []
        n_done = len(self.finished)
        self._step_prefill_tokens = 0
        sched = self.scheduler
        sched.begin_step(self.steps,
                         math.inf if self.prefill_chunk is None
                         else self.prefill_chunk)
        preempted_rids: List[int] = []
        pending: List[PrefillAssignment] = []
        while True:
            plan = sched.schedule()
            for req, slot in plan.preempted:
                preempted_rids.append(req.rid)
                self.runner.clear_row(slot)
                self.runner.clear_slot(slot)
                # a deferred chunk whose request just lost its slot and
                # blocks must not execute: the launch would write into
                # freed (possibly re-allocated) pages
                pending = [pa for pa in pending if pa.req.rid != req.rid]
            for req in plan.admitted:
                self.runner.set_row(req.slot,
                                    self.block_mgr.tables[req.rid].blocks)
            self._apply_restores(plan.admitted)
            self._apply_copies()
            for pa in plan.prefills:
                pa.req.prefilled = pa.start + pa.n
                pending.append(pa)
            if plan.idle:
                break

        # ---- launch 1: pending chunks + already-decoding requests
        # merge a request's chunks (contiguous by construction) into one
        # segment; keep first-assignment order for emission parity
        chunks = {}                       # rid -> [req, tokens, start]
        order: List[int] = []
        for pa in pending:
            tok = list(pa.req.chain()[pa.start:pa.start + pa.n])
            self._step_prefill_tokens += pa.n
            if pa.req.rid in chunks:
                ent = chunks[pa.req.rid]
                if ent[2] + len(ent[1]) != pa.start:
                    raise KVInvariantError(
                        f"non-contiguous fused prefill chunks for request "
                        f"{pa.req.rid}: have [{ent[2]}, "
                        f"{ent[2] + len(ent[1])}), next starts {pa.start}")
                ent[1].extend(tok)
            else:
                chunks[pa.req.rid] = [pa.req, tok, pa.start]
                order.append(pa.req.rid)
        pending_rids = set(order)
        decs = list(plan.decodes)
        old_decodes = [r for r in decs if r.rid not in pending_rids]
        segments = []
        seg_of = {}
        for rid in order:
            req, tok, start = chunks[rid]
            seg_of[rid] = len(segments)
            segments.append((req.slot, tok, start))
        for r in old_decodes:
            seg_of[r.rid] = len(segments)
            segments.append((r.slot, [r.generated[-1]], r.pos_next))
        h1 = self.runner.forward_batch(segments) if segments else None

        # ---- prefill lifecycle effects, in plan order
        for rid in order:
            req = chunks[rid][0]
            self.block_mgr.commit(req.rid, req.prefilled)
            if not req.prefill_done:
                continue
            if not req.generated:         # first admission: emit token 0
                req.metrics.admit_step = self.steps
                first = sample_token(h1[seg_of[rid]], req.params, 0)
                reason = self._emit(req, first, events)
                self._extend(req, first)
                if reason is not None:
                    self._finish(req, reason)
            else:                         # resume: decode re-feeds the tail
                self._extend(req, req.generated[-1])

        # ---- launch 2: requests whose prefill completed this step decode
        # their freshly sampled / re-fed token
        new_decodes = [r for r in decs
                       if r.rid in pending_rids and not r.done]
        h2 = None
        idx2 = {}
        if new_decodes:
            segs2 = []
            for i, r in enumerate(new_decodes):
                idx2[r.rid] = i
                segs2.append((r.slot, [r.generated[-1]], r.pos_next))
            h2 = self.runner.forward_batch(segs2)

        # ---- decode emissions, in plan.decodes order (legacy parity)
        for r in decs:
            if r.done:
                continue
            logits = (h2[idx2[r.rid]] if r.rid in pending_rids
                      else h1[seg_of[r.rid]])
            if r.params.greedy:
                nxt = int(np.asarray(jnp.argmax(logits)))
            else:
                nxt = sample_token(logits, r.params, len(r.generated))
            r.metrics.decode_steps += 1
            reason = self._emit(r, nxt, events)
            self.block_mgr.commit(
                r.rid, r.prompt_total + len(r.generated) - 1)
            self._extend(r, nxt)
            if reason is not None:
                self._finish(r, reason)
        return StepOutput(self.steps, tuple(events),
                          tuple(r.rid for r in self.finished[n_done:]),
                          len(self.active()), sched.num_queued(),
                          prefill_tokens=self._step_prefill_tokens,
                          preempted=tuple(preempted_rids))

    def _finish(self, req: GenRequest, reason: FinishReason):
        slot = req.slot
        req.done = True
        req.finish_reason = reason
        req.metrics.finish_step = self.steps
        self.scheduler.release(req)
        self.runner.clear_row(slot)
        self.runner.clear_slot(slot)
        self.finished.append(req)

    def preempt(self, req: GenRequest):
        """Forcibly evict a running request regardless of policy — the
        same mechanics a pressure-driven preemption uses. Its blocks are
        released (committed prefix stays cached under ``prefix_cache``),
        it rejoins the admission queue, and its token stream continues
        bit-exactly after re-admission."""
        self._check_live()
        slot = self.scheduler.force_preempt(req)
        self.runner.clear_row(slot)
        self.runner.clear_slot(slot)

    def run(self, max_steps: int = 10_000) -> List[StepOutput]:
        self._check_live()
        outs = []
        while self.has_work() and max_steps:
            outs.append(self.step())
            max_steps -= 1
        return outs

    def generate(self, prompt: Sequence[int],
                 params: Union[SamplingParams, int, None] = None, *,
                 prefix_embeds=None,
                 max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Submit one request (eagerly, before the first ``next()``) and
        drive the engine until it finishes, yielding its TokenEvents as
        they are emitted. Other in-flight requests advance normally but
        their events are not yielded — for multiplexed streaming, drive
        ``step()`` yourself and demux ``StepOutput.events`` by rid."""
        req = self.submit(prompt, params, prefix_embeds=prefix_embeds)

        def _drive() -> Iterator[TokenEvent]:
            for _ in range(max_steps):
                if req.done:
                    return
                out = self.step()
                for ev in out.events:
                    if ev.rid == req.rid:
                        yield ev
            if not req.done:
                raise RuntimeError(f"request {req.rid} not finished after "
                                   f"{max_steps} steps (admission starved?)")

        return _drive()

    # ---------------------------------------------------- consolidation
    def n_attn_layers(self, migrated_only: bool = False) -> int:
        """Attention layers across the pipeline. ``migrated_only`` counts
        only the layers whose KV crosses the network in a scale-down —
        every stage except the surviving target (worker 0) — i.e. the
        `n_layers` the BlockManager's migration_bytes quote refers to."""
        per_period = sum(1 for m in self.cfg.mixer_pattern if m == "attn")
        workers = self.runner.workers[1:] if migrated_only \
            else self.runner.workers
        return per_period * sum(p1 - p0 for p0, p1 in
                                (w.periods for w in workers))

    def consolidated(self, full_params: dict) -> "Engine":
        """Scale-down: gather the distributed KV/state to one standalone
        worker holding the full model; in-flight requests continue —
        including half-prefilled ones, whose allocated blocks are live and
        move with them. In paged mode the gather is block-granular (§6.2:
        only the blocks the BlockManager reports live move, each shared
        block exactly once) and ``last_migration_bytes`` is the exact byte
        count gathered. Refcount-zero prefix-cache blocks are dropped from
        the index rather than shipped — correctness needs only the live
        set (a preempted request therefore re-prefills from scratch after
        a consolidation; its stream is still bit-exact). The scheduling
        policy and the waiting/preempted pools carry over."""
        self._check_live()
        eng = Engine(self.cfg, [full_params], self.max_batch, self.max_seq,
                     self.block_mgr.block_size, paged=self.paged,
                     prefix_cache=self.prefix_cache,
                     prefill_chunk=self.prefill_chunk,
                     policy=self.scheduler.policy,
                     kv_dtype=self.kv_dtype, fused=self.fused,
                     sanitize=False)   # the successor adopts OUR sanitizer
        stage_caches = [w.cache for w in self.runner.workers]
        if self.paged:
            self.block_mgr.drop_unreferenced_cache()
            live_rids = [r.rid for r in self.active()]
            live = self.block_mgr.blocks_of(live_rids)
            cache, moved = gather_stage_caches_with_bytes(
                stage_caches, live_blocks=live, target_stage=0,
                tracer=self.block_mgr.tracer)
            if self.sanitizer is not None:
                self.sanitizer.check_migration(
                    moved, self.block_mgr.migration_bytes(
                        live_rids,
                        self.n_attn_layers(migrated_only=True)))
            self.last_migration_bytes = moved
            eng.last_migration_bytes = moved
        else:
            cache = gather_stage_caches(stage_caches)
        eng.runner.workers[0].cache = cache
        eng.block_mgr = self.block_mgr
        eng.scheduler.adopt(self.scheduler, self.block_mgr)
        if self.sanitizer is not None:
            # rebind the tracer endpoints (runner / workers; the shared
            # BlockManager already carries bm.tracer) BEFORE rebuild_rows
            # so the successor's row writes are observed
            eng.sanitizer = self.sanitizer
            self.sanitizer.rebind(eng)
        eng.runner.rebuild_rows(eng.active(), self.block_mgr.tables)
        eng._rid = self._rid
        eng.finished = self.finished
        eng.steps = self.steps            # keep step metrics continuous
        if self.kv_tier is not None:
            # the shared BlockManager carries the hook list across the
            # swap, but our hook closes over the runner being retired —
            # rebind the spill path to the successor. (The cold cached
            # pages dropped above already spilled through OUR runner,
            # which was still live — a consolidation demotes the prefix
            # cache to the host tier instead of discarding it.)
            self._remove_spill_hook()
            eng.kv_tier = self.kv_tier
            eng._install_spill_hook()
        return eng

    def scale_up(self, full_params: dict) -> List["Engine"]:
        """Scale-up: every stage becomes a standalone engine; in-flight
        requests (with gathered cache) stay on the first."""
        first = self.consolidated(full_params)
        others = []
        for _ in range(1, len(self.runner.workers)):
            others.append(Engine(self.cfg, [full_params], self.max_batch,
                                 self.max_seq, self.block_mgr.block_size,
                                 paged=self.paged,
                                 prefix_cache=self.prefix_cache,
                                 prefill_chunk=self.prefill_chunk,
                                 policy=self.scheduler.policy,
                                 kv_tier=self.kv_tier,
                                 kv_dtype=self.kv_dtype,
                                 fused=self.fused,
                                 sanitize=self.sanitizer is not None))
        return [first] + others

    def retire(self):
        """Mark this engine unusable after a ServingEndpoint swapped in
        its consolidated successor. The successor aliases this engine's
        block manager, queues, and slots — clear our references and drop
        worker caches so any stale use raises (``_check_live``) instead of
        silently corrupting block tables it no longer owns."""
        self.retired = True
        self._remove_spill_hook()         # closure binds the dead runner
        self.scheduler.clear()
        self.runner.retire()
