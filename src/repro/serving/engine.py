"""Continuous-batching serving engine over a pipeline-parallel worker group.

Functional twin of the DES: real JAX compute (CPU-scale models), real KV
caches, real consolidation — `consolidated()` performs the §6.2 KV gather
and returns a standalone engine that must continue every in-flight request
bit-exactly (tested in tests/test_engine.py).
"""

from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.serving.kvcache import BlockManager
from repro.serving.migration import gather_stage_caches
from repro.serving.worker import StageWorker


@dataclass
class GenRequest:
    rid: int
    prompt: List[int]
    max_new: int
    prefix_embeds: Optional[np.ndarray] = None
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False

    @property
    def pos_next(self) -> int:
        """Cache position of the next token to feed."""
        plen = len(self.prompt) + (0 if self.prefix_embeds is None
                                   else self.prefix_embeds.shape[0])
        return plen + len(self.generated) - 1


class Engine:
    def __init__(self, cfg: ModelConfig, stage_params: Sequence[dict],
                 max_batch: int = 4, max_seq: int = 128,
                 block_size: int = 16):
        self.cfg = cfg
        self.model = Model(cfg)
        n = len(stage_params)
        self.workers = [StageWorker(cfg, p, n, i, max_batch, max_seq)
                        for i, p in enumerate(stage_params)]
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.slots: List[Optional[GenRequest]] = [None] * max_batch
        self.queue: collections.deque = collections.deque()
        kv_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * \
            jnp.dtype(cfg.dtype).itemsize
        self.block_mgr = BlockManager(
            n_blocks=max_batch * (max_seq // block_size + 1),
            block_size=block_size, bytes_per_token=max(kv_per_tok, 1))
        self._rid = itertools.count()
        self.finished: List[GenRequest] = []
        self.steps = 0

    # ------------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int], max_new: int,
               prefix_embeds=None) -> GenRequest:
        req = GenRequest(next(self._rid), list(prompt), max_new,
                         prefix_embeds)
        self.queue.append(req)
        return req

    # -------------------------------------------------------------- admit
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            req.slot = slot
            self.slots[slot] = req
            self._prefill(req)

    def _prefill(self, req: GenRequest):
        tokens = jnp.asarray([req.prompt], jnp.int32)
        plen = len(req.prompt)
        prefix = None
        total = plen
        if req.prefix_embeds is not None:
            prefix = jnp.asarray(req.prefix_embeds)[None]
            total += prefix.shape[1]
        positions = jnp.arange(total, dtype=jnp.int32)[None]
        self.block_mgr.allocate(req.rid, total)
        h = tokens
        for w in self.workers:
            h = w.prefill_slot(h, req.slot, positions, prefix_embeds=prefix)
        first = int(jnp.argmax(h[0, 0]))
        req.generated.append(first)
        self.block_mgr.extend(req.rid)

    # -------------------------------------------------------------- step
    def active(self) -> List[GenRequest]:
        return [r for r in self.slots if r is not None]

    def step(self):
        """One scheduler iteration: admit then one decode for all slots."""
        self._admit()
        reqs = self.active()
        if not reqs:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        positions = np.zeros((self.max_batch, 1), np.int32)
        for r in reqs:
            tokens[r.slot, 0] = r.generated[-1]
            positions[r.slot, 0] = r.pos_next
        h = jnp.asarray(tokens)
        pos = jnp.asarray(positions)
        for w in self.workers:
            h = w.decode(h, pos)
        nxt = np.asarray(jnp.argmax(h[:, 0], axis=-1))
        self.steps += 1
        for r in list(reqs):
            if len(r.generated) >= r.max_new:
                self._finish(r)
                continue
            r.generated.append(int(nxt[r.slot]))
            self.block_mgr.extend(r.rid)
            if len(r.generated) >= r.max_new:
                self._finish(r)

    def _finish(self, req: GenRequest):
        req.done = True
        self.slots[req.slot] = None
        self.block_mgr.free(req.rid)
        for w in self.workers:
            w.clear_slot(req.slot)
        self.finished.append(req)

    def run(self, max_steps: int = 10_000):
        while (self.queue or self.active()) and max_steps:
            self.step()
            max_steps -= 1

    # ---------------------------------------------------- consolidation
    def consolidated(self, full_params: dict) -> "Engine":
        """Scale-down: gather the distributed KV/state to one standalone
        worker holding the full model; in-flight requests continue."""
        eng = Engine(self.cfg, [full_params], self.max_batch, self.max_seq,
                     self.block_mgr.block_size)
        eng.workers[0].cache = gather_stage_caches(
            [w.cache for w in self.workers])
        eng.slots = list(self.slots)
        eng.queue = self.queue
        eng.block_mgr = self.block_mgr
        eng._rid = self._rid
        eng.finished = self.finished
        return eng

    def scale_up(self, full_params: dict) -> List["Engine"]:
        """Scale-up: every stage becomes a standalone engine; in-flight
        requests (with gathered cache) stay on the first."""
        first = self.consolidated(full_params)
        others = []
        for _ in range(1, len(self.workers)):
            others.append(Engine(self.cfg, [full_params], self.max_batch,
                                 self.max_seq, self.block_mgr.block_size))
        return [first] + others
