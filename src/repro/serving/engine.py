"""Continuous-batching serving engine over a pipeline-parallel worker group.

Functional twin of the DES: real JAX compute (CPU-scale models), real KV
caches, real consolidation. The engine is organised around *request
lifecycles* (see serving/api.py): ``submit(prompt, SamplingParams)``
returns a request handle, every ``step()`` returns a ``StepOutput`` whose
``TokenEvent``s let callers stream, requests finish with a
``FinishReason`` (length / eos / stop_token) and carry ``RequestMetrics``
in scheduler steps.

Most callers should not hold an Engine directly: ``ServingEndpoint``
(serving/endpoint.py) is the stable handle that swaps engines in place
across §6.2 consolidation / scale-up. ``consolidated()`` / ``scale_up()``
remain on the engine for callers that need the raw object (bit-exactness
tests), but the endpoint additionally *retires* the source engine so a
stale reference raises instead of silently corrupting the block tables it
no longer owns.

KV layouts (``paged`` flag, default from ``ops.decode_mode()``):
  * contiguous — per-slot (B, Smax) caches, the seed behaviour.
  * paged — attention KV lives in a shared page pool addressed through the
    BlockManager's per-request block tables: prefill writes into allocated
    blocks, decode appends through ``extend``, admission defers requests
    when the pool can't cover them (no MemoryError mid-flight), and
    consolidation gathers exactly the live blocks.

Paged engines additionally support (attention-only decoder models):
  * ``prefix_cache=True`` — admission matches each prompt against the
    BlockManager's content-addressed prefix index and prefills only the
    suffix; shared blocks are reference-counted, a fully-cached prompt
    copies its last block on write, and finished requests' blocks stay
    cached (LRU-evicted before admission ever defers). Greedy outputs
    are bit-exact with the uncached engine.
  * ``prefill_chunk=N`` — prefill runs in chunks of at most N tokens per
    step, interleaved with decode (*mixed steps*): a long prompt no
    longer stalls in-flight decodes for a whole forward, so one
    request's TTFT can't starve everyone else's ITL. Half-prefilled
    requests survive §6.2 consolidation.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import math
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.model import Model
from repro.serving.api import (FinishReason, RequestMetrics, RequestOutput,
                               SamplingParams, StepOutput, TokenEvent,
                               sample_token)
from repro.serving.kvcache import BlockManager
from repro.serving.migration import (gather_stage_caches,
                                     gather_stage_caches_with_bytes)
from repro.serving.worker import StageWorker


@dataclass
class GenRequest:
    """Opaque per-request handle returned by ``submit`` — callers read
    ``generated``/``done``/``finish_reason``/``metrics`` and call
    ``output()``; everything else is engine-internal."""
    rid: int
    prompt: List[int]
    params: SamplingParams
    prefix_embeds: Optional[np.ndarray] = None
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    finish_reason: Optional[FinishReason] = None
    metrics: RequestMetrics = field(default_factory=RequestMetrics)
    prefilled: int = 0          # prompt rows with KV computed (incl. cached)

    @property
    def max_new(self) -> int:
        return self.params.max_new

    @property
    def prompt_total(self) -> int:
        """Prompt tokens incl. any prefix embeddings."""
        return len(self.prompt) + (0 if self.prefix_embeds is None
                                   else self.prefix_embeds.shape[0])

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt_total

    @property
    def pos_next(self) -> int:
        """Cache position of the next token to feed."""
        return self.prompt_total + len(self.generated) - 1

    def output(self) -> RequestOutput:
        return RequestOutput(self.rid, tuple(self.prompt),
                             tuple(self.generated), self.finish_reason,
                             dataclasses.replace(self.metrics))


class Engine:
    def __init__(self, cfg: ModelConfig, stage_params: Sequence[dict],
                 max_batch: int = 4, max_seq: int = 128,
                 block_size: int = 16, paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None):
        self.cfg = cfg
        self.model = Model(cfg)
        if paged is None:
            paged = ops.decode_mode() == "paged"
        self.paged = paged
        if prefix_cache or prefill_chunk is not None:
            if not paged:
                raise ValueError("prefix_cache / prefill_chunk need the "
                                 "paged KV layout (Engine(paged=True))")
            if any(m != "attn" for m in cfg.mixer_pattern) or cfg.is_encdec:
                raise ValueError(
                    "prefix_cache / prefill_chunk need an attention-only "
                    "decoder: recurrent mixer state is not block-shareable "
                    f"({cfg.name})")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self.max_batch = max_batch
        self.max_seq = max_seq
        kv_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * \
            jnp.dtype(cfg.dtype).itemsize
        n_blocks = max_batch * (max_seq // block_size + 1)
        self.block_mgr = BlockManager(
            n_blocks=n_blocks, block_size=block_size,
            bytes_per_token=max(kv_per_tok, 1), prefix_cache=prefix_cache)
        # one extra trash page: idle slots' block-table rows point here so
        # their (unused) decode writes never land in a live page
        self._null_page = n_blocks
        self._table_width = max_seq // block_size + 1
        n = len(stage_params)
        self.workers = [StageWorker(cfg, p, n, i, max_batch, max_seq,
                                    paged=paged, n_pages=n_blocks + 1,
                                    page_size=block_size)
                        for i, p in enumerate(stage_params)]
        self.slots: List[Optional[GenRequest]] = [None] * max_batch
        self.queue: collections.deque = collections.deque()
        self._rid = itertools.count()
        self.finished: List[GenRequest] = []
        self.steps = 0
        self.retired = False
        self.last_migration_bytes: Optional[int] = None
        # per-step prefill token budget (set by step())
        self._prefill_budget: float = math.inf
        self._step_prefill_tokens: int = 0

    def _check_live(self):
        if self.retired:
            raise RuntimeError(
                "Engine has been retired: its ServingEndpoint swapped in a "
                "consolidated successor that owns the block tables — use "
                "the endpoint handle, not the stale engine")

    # ------------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int],
               params: Union[SamplingParams, int, None] = None, *,
               max_new: Optional[int] = None,
               prefix_embeds=None) -> GenRequest:
        self._check_live()
        if isinstance(params, int):       # legacy submit(prompt, max_new)
            params = SamplingParams(max_new=params)
        if max_new is not None:           # legacy submit(..., max_new=n)
            if params is not None:
                raise TypeError("pass either SamplingParams or max_new")
            params = SamplingParams(max_new=max_new)
        if params is None:
            params = SamplingParams()
        req = GenRequest(next(self._rid), list(prompt), params,
                         prefix_embeds)
        req.metrics.submit_step = self.steps
        if req.prompt_total + params.max_new > self.max_seq:
            raise ValueError(
                f"request needs {req.prompt_total + params.max_new} cache "
                f"slots (prompt {req.prompt_total} + max_new "
                f"{params.max_new}) > max_seq={self.max_seq}")
        self.queue.append(req)
        return req

    # -------------------------------------------------------------- admit
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _can_admit(self, req: GenRequest) -> bool:
        """Admission control, one authoritative BlockManager check: the
        pool must cover this request's worst-case total (prompt + decode
        tail — which subsumes the prompt itself) on top of the worst-case
        tails already reserved by in-flight requests, so ``extend`` can
        never fail mid-flight. (submit() already bounds every request to
        max_seq total tokens.) Deliberately conservative under the prefix
        cache: a hit only means *fewer* fresh blocks are taken."""
        bm = self.block_mgr
        reserved = 0
        for r in self.active():
            held = len(bm.tables[r.rid].blocks)
            reserved += max(0, bm.blocks_needed(r.prompt_total + r.max_new)
                            - held)
        need = bm.blocks_needed(req.prompt_total + req.max_new)
        return bm.free_blocks - reserved >= need

    def _admit(self, events: List[TokenEvent]):
        """Admit from the queue head while slots, blocks, and the step's
        prefill budget allow. A request whose prefill token already
        satisfies its finish condition (max_new=1, eos, stop token)
        finishes here and frees its slot immediately — it never occupies
        a decode step."""
        while self.queue and self._prefill_budget > 0:
            free = self._free_slots()
            if not free:
                break
            if not self._can_admit(self.queue[0]):
                break                     # defer until blocks free up
            req = self.queue.popleft()
            req.slot = free[0]
            self.slots[req.slot] = req
            self._allocate(req)
            self._prefill_progress(req, events)

    def _allocate(self, req: GenRequest):
        """Build the request's block table. With the prefix cache on, the
        prompt's token chain is matched against the index: the shared
        blocks need no prefill compute (``prefilled`` starts past them)
        and any copy-on-write of a fully-cached prompt's last block is
        applied to the worker pools right here, before anything reads or
        evicts the source page."""
        tokens = None
        if self.prefix_cache and req.prefix_embeds is None:
            # prefix embeddings are not part of the token chain — those
            # requests prefill from scratch
            tokens = req.prompt
        table = self.block_mgr.allocate(req.rid, req.prompt_total,
                                        tokens=tokens)
        req.prefilled = table.cached_tokens
        req.metrics.cached_tokens = table.cached_tokens
        for src, dst in self.block_mgr.drain_copies():
            for w in self.workers:
                w.copy_pages(src, dst)

    def _block_tables(self, decode: bool = False) -> jnp.ndarray:
        """(B, nb) int32 page ids from the BlockManager; idle slots (and
        tails past a request's live blocks) point at the null page. For
        ``decode``, half-prefilled slots are nulled too: they take no part
        in the decode batch and their dummy writes must not land in live
        (possibly shared) pages."""
        bt = np.full((self.max_batch, self._table_width), self._null_page,
                     np.int32)
        for r in self.active():
            if decode and not r.prefill_done:
                continue
            blocks = self.block_mgr.tables[r.rid].blocks
            bt[r.slot, :len(blocks)] = blocks
        return jnp.asarray(bt)

    def _prefill_progress(self, req: GenRequest, events: List[TokenEvent]):
        """Advance this request's prefill within the step's token budget.
        Monolithic engines (prefill_chunk=None) run the whole remainder in
        one forward; chunked engines stop at the budget and resume next
        step. Emits the first token when the prompt completes."""
        while not req.prefill_done and self._prefill_budget > 0:
            n = req.prompt_total - req.prefilled
            if req.prefix_embeds is None:
                n = min(n, self._prefill_budget)
            # prefix-embed prompts prefill monolithically (their embeds
            # are not re-sliceable per chunk); they still charge the
            # budget so co-resident prefills stay bounded
            self._prefill_chunk(req, n, events)
            self._prefill_budget -= n
            self._step_prefill_tokens += n

    def _prefill_chunk(self, req: GenRequest, n: int,
                       events: List[TokenEvent]):
        """One prefill forward over the next ``n`` prompt rows."""
        start = req.prefilled
        prefix = None
        if req.prefix_embeds is not None:
            assert start == 0 and n == req.prompt_total
            prefix = jnp.asarray(req.prefix_embeds)[None]
            tok = req.prompt
        else:
            tok = req.prompt[start:start + n]
        h = jnp.asarray([tok], jnp.int32)
        positions = jnp.arange(start, start + n, dtype=jnp.int32)[None]
        bt = None
        if self.paged:
            bt = self._block_tables()[req.slot:req.slot + 1]
        for w in self.workers:
            h = w.prefill_slot(h, req.slot, positions, prefix_embeds=prefix,
                               block_tables=bt, hist_len=start)
        req.prefilled = start + n
        self.block_mgr.commit(req.rid, req.prefilled)
        if req.prefill_done:
            req.metrics.admit_step = self.steps
            first = sample_token(h[0, 0], req.params, 0)
            reason = self._emit(req, first, events)
            self.block_mgr.extend(req.rid, token=first)
            if reason is not None:
                self._finish(req, reason)

    # -------------------------------------------------------------- step
    def active(self) -> List[GenRequest]:
        return [r for r in self.slots if r is not None]

    def _finish_reason(self, req: GenRequest,
                       token: int) -> Optional[FinishReason]:
        sp = req.params
        if sp.eos_token is not None and token == sp.eos_token:
            return FinishReason.EOS
        if token in sp.stop_tokens:
            return FinishReason.STOP_TOKEN
        if len(req.generated) >= sp.max_new:
            return FinishReason.LENGTH
        return None

    def _emit(self, req: GenRequest, token: int,
              events: List[TokenEvent]) -> Optional[FinishReason]:
        req.generated.append(token)
        req.metrics.n_tokens = len(req.generated)
        reason = self._finish_reason(req, token)
        events.append(TokenEvent(req.rid, token, reason))
        return reason

    def step(self) -> StepOutput:
        """One scheduler iteration: resume half-prefilled residents, admit
        from the queue, then one decode for every fully-prefilled slot —
        a *mixed* step when chunked prefill and decode coexist. Returns
        the step's newly emitted token events (streaming)."""
        self._check_live()
        self.steps += 1
        events: List[TokenEvent] = []
        n_done = len(self.finished)
        self._prefill_budget = (math.inf if self.prefill_chunk is None
                                else self.prefill_chunk)
        self._step_prefill_tokens = 0
        # residents first (admission order), then the queue: a long prompt
        # mid-prefill keeps priority over newly arriving requests
        for r in sorted(self.active(), key=lambda r: r.rid):
            if not r.prefill_done:
                self._prefill_progress(r, events)
        self._admit(events)
        reqs = [r for r in self.active() if r.prefill_done]
        if reqs:
            tokens = np.zeros((self.max_batch, 1), np.int32)
            positions = np.zeros((self.max_batch, 1), np.int32)
            for r in reqs:
                tokens[r.slot, 0] = r.generated[-1]
                positions[r.slot, 0] = r.pos_next
            h = jnp.asarray(tokens)
            pos = jnp.asarray(positions)
            bt = self._block_tables(decode=True) if self.paged else None
            for w in self.workers:
                h = w.decode(h, pos, block_tables=bt)
            greedy = None
            if any(r.params.greedy for r in reqs):
                greedy = np.asarray(jnp.argmax(h[:, 0], axis=-1))
            for r in list(reqs):
                if r.params.greedy:
                    nxt = int(greedy[r.slot])
                else:
                    nxt = sample_token(h[r.slot, 0], r.params,
                                       len(r.generated))
                r.metrics.decode_steps += 1
                reason = self._emit(r, nxt, events)
                # the fed token's KV is now material through pos_next + 1
                self.block_mgr.commit(
                    r.rid, r.prompt_total + len(r.generated) - 1)
                self.block_mgr.extend(r.rid, token=nxt)
                if reason is not None:
                    self._finish(r, reason)
        return StepOutput(self.steps, tuple(events),
                          tuple(r.rid for r in self.finished[n_done:]),
                          len(self.active()), len(self.queue),
                          prefill_tokens=self._step_prefill_tokens)

    def _finish(self, req: GenRequest, reason: FinishReason):
        req.done = True
        req.finish_reason = reason
        req.metrics.finish_step = self.steps
        self.slots[req.slot] = None
        self.block_mgr.free(req.rid)
        for w in self.workers:
            w.clear_slot(req.slot)
        self.finished.append(req)

    def run(self, max_steps: int = 10_000) -> List[StepOutput]:
        self._check_live()
        outs = []
        while (self.queue or self.active()) and max_steps:
            outs.append(self.step())
            max_steps -= 1
        return outs

    def generate(self, prompt: Sequence[int],
                 params: Union[SamplingParams, int, None] = None, *,
                 prefix_embeds=None,
                 max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Submit one request (eagerly, before the first ``next()``) and
        drive the engine until it finishes, yielding its TokenEvents as
        they are emitted. Other in-flight requests advance normally but
        their events are not yielded — for multiplexed streaming, drive
        ``step()`` yourself and demux ``StepOutput.events`` by rid."""
        req = self.submit(prompt, params, prefix_embeds=prefix_embeds)

        def _drive() -> Iterator[TokenEvent]:
            for _ in range(max_steps):
                if req.done:
                    return
                out = self.step()
                for ev in out.events:
                    if ev.rid == req.rid:
                        yield ev
            if not req.done:
                raise RuntimeError(f"request {req.rid} not finished after "
                                   f"{max_steps} steps (admission starved?)")

        return _drive()

    # ---------------------------------------------------- consolidation
    def n_attn_layers(self, migrated_only: bool = False) -> int:
        """Attention layers across the pipeline. ``migrated_only`` counts
        only the layers whose KV crosses the network in a scale-down —
        every stage except the surviving target (worker 0) — i.e. the
        `n_layers` the BlockManager's migration_bytes quote refers to."""
        per_period = sum(1 for m in self.cfg.mixer_pattern if m == "attn")
        workers = self.workers[1:] if migrated_only else self.workers
        return per_period * sum(p1 - p0 for p0, p1 in
                                (w.periods for w in workers))

    def consolidated(self, full_params: dict) -> "Engine":
        """Scale-down: gather the distributed KV/state to one standalone
        worker holding the full model; in-flight requests continue —
        including half-prefilled ones, whose allocated blocks are live and
        move with them. In paged mode the gather is block-granular (§6.2:
        only the blocks the BlockManager reports live move, each shared
        block exactly once) and ``last_migration_bytes`` is the exact byte
        count gathered. Refcount-zero prefix-cache blocks are dropped from
        the index rather than shipped — correctness needs only the live
        set."""
        self._check_live()
        eng = Engine(self.cfg, [full_params], self.max_batch, self.max_seq,
                     self.block_mgr.block_size, paged=self.paged,
                     prefix_cache=self.prefix_cache,
                     prefill_chunk=self.prefill_chunk)
        stage_caches = [w.cache for w in self.workers]
        if self.paged:
            self.block_mgr.drop_unreferenced_cache()
            live = self.block_mgr.blocks_of(r.rid for r in self.active())
            cache, moved = gather_stage_caches_with_bytes(
                stage_caches, live_blocks=live, target_stage=0)
            self.last_migration_bytes = moved
            eng.last_migration_bytes = moved
        else:
            cache = gather_stage_caches(stage_caches)
        eng.workers[0].cache = cache
        eng.slots = list(self.slots)
        eng.queue = self.queue
        eng.block_mgr = self.block_mgr
        eng._rid = self._rid
        eng.finished = self.finished
        eng.steps = self.steps            # keep step metrics continuous
        return eng

    def scale_up(self, full_params: dict) -> List["Engine"]:
        """Scale-up: every stage becomes a standalone engine; in-flight
        requests (with gathered cache) stay on the first."""
        first = self.consolidated(full_params)
        others = []
        for _ in range(1, len(self.workers)):
            others.append(Engine(self.cfg, [full_params], self.max_batch,
                                 self.max_seq, self.block_mgr.block_size,
                                 paged=self.paged,
                                 prefix_cache=self.prefix_cache,
                                 prefill_chunk=self.prefill_chunk))
        return [first] + others

    def retire(self):
        """Mark this engine unusable after a ServingEndpoint swapped in
        its consolidated successor. The successor aliases this engine's
        block manager, queue, and slots — clear our references and drop
        worker caches so any stale use raises (``_check_live``) instead of
        silently corrupting block tables it no longer owns."""
        self.retired = True
        self.slots = [None] * self.max_batch
        self.queue = collections.deque()
        for w in self.workers:
            w.retire()
        self.workers = []
