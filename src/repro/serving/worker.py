"""Stage worker: holds one pipeline stage's parameter slice and the KV/state
cache for its periods; executes stage-local prefill/decode with jitted fns.

Decoder-only families. Encoder-decoder (whisper) serves single-worker —
see DESIGN.md §5.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.model import Model


class StageWorker:
    def __init__(self, cfg: ModelConfig, stage_params: dict, n_stages: int,
                 stage: int, max_batch: int, max_seq: int):
        assert not cfg.is_encdec or n_stages == 1, \
            "enc-dec serves single-worker (DESIGN.md §5)"
        self.cfg = cfg
        self.model = Model(cfg)
        self.n_stages = n_stages
        self.stage = stage
        self.first = stage == 0
        self.last = stage == n_stages - 1
        p0, p1 = self.model.stage_ranges(n_stages)[stage]
        self.periods = (p0, p1)
        self.params = stage_params
        self.max_batch = max_batch
        self.max_seq = max_seq
        dt = jnp.dtype(cfg.dtype)
        self.cache = transformer.init_cache(cfg, max_batch, max_seq, dt,
                                            n_periods=p1 - p0)
        self._prefill_fn = jax.jit(self._prefill_impl,
                                   static_argnames=("with_prefix",))
        self._decode_fn = jax.jit(self._decode_impl)

    # ----------------------------------------------------------- impl fns
    def _prefill_impl(self, params, x_in, positions, fresh_cache,
                      prefix_embeds=None, *, with_prefix=False):
        cfg = self.cfg
        if self.first:
            x = transformer.embed(cfg, params, x_in, positions,
                                  prefix_embeds=prefix_embeds
                                  if with_prefix else None,
                                  dtype=jnp.dtype(cfg.dtype))
        else:
            x = x_in
        x, new_cache, _ = transformer.run_blocks(
            cfg, params["blocks"], x, positions, cache=fresh_cache)
        out = transformer.head(cfg, params, x[:, -1:]) if self.last else x
        return out, new_cache

    def _decode_impl(self, params, x_in, positions, cache):
        cfg = self.cfg
        if self.first:
            x = transformer.embed(cfg, params, x_in, positions,
                                  dtype=jnp.dtype(cfg.dtype))
        else:
            x = x_in
        x, new_cache, _ = transformer.run_blocks(
            cfg, params["blocks"], x, positions, cache=cache, decode=True)
        out = transformer.head(cfg, params, x) if self.last else x
        return out, new_cache

    # ------------------------------------------------------------ public
    def prefill_slot(self, x_in, slot: int, positions, prefix_embeds=None):
        """Prefill one request (batch 1 inputs) into cache slot `slot`.
        Recurrent states start from zero (fresh cache), then results are
        scattered into the live batched cache."""
        p0, p1 = self.periods
        seq = positions.shape[1]
        dt = jnp.dtype(self.cfg.dtype)
        fresh = transformer.init_cache(self.cfg, 1, self.max_seq, dt,
                                       n_periods=p1 - p0)
        out, one_cache = self._prefill_fn(self.params, x_in, positions,
                                          fresh, prefix_embeds,
                                          with_prefix=prefix_embeds is not None)
        self.cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype),
                (0, slot) + (0,) * (full.ndim - 2)),
            self.cache, one_cache)
        return out

    def decode(self, x_in, positions):
        out, self.cache = self._decode_fn(self.params, x_in, positions,
                                          self.cache)
        return out

    def clear_slot(self, slot: int):
        """Zero a slot's recurrent state (attn KV needs no clear: masked)."""
        self.cache = jax.tree.map(
            lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])),
            self.cache)
