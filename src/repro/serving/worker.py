"""Stage worker: holds one pipeline stage's parameter slice and the KV/state
cache for its periods; executes stage-local prefill/decode with jitted fns.

Two attention KV layouts:
  * slot-contiguous (default): (P, B, Smax, Hkv, hd) per attn period.
  * paged: a shared page pool (P, N, bs, Hkv, hd) addressed through
    per-request block tables handed in by the engine's BlockManager —
    prefill scatters prompt K/V into allocated pages, decode appends
    through the same tables. Recurrent states (mamba/rwkv) stay
    slot-indexed in both layouts.

Decoder-only families. Encoder-decoder (whisper) serves single-worker —
see DESIGN.md §5.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.model import Model


class StageWorker:
    def __init__(self, cfg: ModelConfig, stage_params: dict, n_stages: int,
                 stage: int, max_batch: int, max_seq: int,
                 paged: bool = False, n_pages: Optional[int] = None,
                 page_size: Optional[int] = None, kv_dtype=None):
        if cfg.is_encdec and n_stages != 1:
            raise ValueError("enc-dec serves single-worker (DESIGN.md §5)")
        if kv_dtype is not None and not paged:
            raise ValueError("kv_dtype override requires the paged layout")
        self.cfg = cfg
        self.model = Model(cfg)
        self.n_stages = n_stages
        self.stage = stage
        self.first = stage == 0
        self.last = stage == n_stages - 1
        p0, p1 = self.model.stage_ranges(n_stages)[stage]
        self.periods = (p0, p1)
        self.params = stage_params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.paged = paged
        self.n_pages = n_pages
        self.page_size = page_size
        self.kv_dtype = kv_dtype
        dt = jnp.dtype(cfg.dtype)
        self.cache = transformer.init_cache(
            cfg, max_batch, max_seq, dt, n_periods=p1 - p0, paged=paged,
            n_pages=n_pages, page_size=page_size, kv_dtype=kv_dtype)
        # hist_len static ⇒ one executable per (chunk, hist) pair; bounded
        # at smoke scale, see prefill_slot docstring
        self._prefill_fn = jax.jit(  # repro-lint: allow[jit-static-shape]
            self._prefill_impl,
            static_argnames=("with_prefix", "hist_len"))
        self._decode_fn = jax.jit(self._decode_impl)
        self._ragged_fn = jax.jit(self._ragged_impl)
        # correctness tracer (analysis/sanitizer.py); None in production
        self.tracer = None

    # ----------------------------------------------------------- impl fns
    def _prefill_impl(self, params, x_in, positions, fresh_cache,
                      block_tables=None, prefix_embeds=None, *,
                      with_prefix=False, hist_len=0):
        cfg = self.cfg
        if self.first:
            x = transformer.embed(cfg, params, x_in, positions,
                                  prefix_embeds=prefix_embeds
                                  if with_prefix else None,
                                  dtype=jnp.dtype(cfg.dtype))
        else:
            x = x_in
        x, new_cache, _ = transformer.run_blocks(
            cfg, params["blocks"], x, positions, cache=fresh_cache,
            block_tables=block_tables, hist_len=hist_len)
        out = transformer.head(cfg, params, x[:, -1:]) if self.last else x
        return out, new_cache

    def _decode_impl(self, params, x_in, positions, cache,
                     block_tables=None):
        cfg = self.cfg
        if self.first:
            x = transformer.embed(cfg, params, x_in, positions,
                                  dtype=jnp.dtype(cfg.dtype))
        else:
            x = x_in
        x, new_cache, _ = transformer.run_blocks(
            cfg, params["blocks"], x, positions, cache=cache, decode=True,
            block_tables=block_tables)
        out = transformer.head(cfg, params, x) if self.last else x
        return out, new_cache

    def _ragged_impl(self, params, x_in, positions, row, valid, tables,
                     out_idx, cache):
        cfg = self.cfg
        if self.first:
            # clamp pad positions (-1) for the embed only (learned pos
            # tables index with them); attention masks on the raw values
            x = transformer.embed(cfg, params, x_in,
                                  jnp.maximum(positions, 0),
                                  dtype=jnp.dtype(cfg.dtype))
        else:
            x = x_in
        x, new_cache, _ = transformer.run_blocks(
            cfg, params["blocks"], x, positions, cache=cache,
            ragged=(tables, row, valid))
        if self.last:
            # gather each segment's last real token before the head —
            # only those rows need logits
            sel = jnp.take(x[0], out_idx, axis=0)[None]
            out = transformer.head(cfg, params, sel)
        else:
            out = x
        return out, new_cache

    # ------------------------------------------------------------ public
    def forward_ragged(self, x_in, positions, row, valid, tables, out_idx):
        """One fused launch over a ragged mixed batch. First stage takes
        tokens (1, T); later stages take hidden states (1, T, d).
        ``positions/row/valid`` (T,) are the per-token descriptors
        (attention.self_attention ragged contract), ``tables`` the full
        block-table matrix, ``out_idx`` (n_out,) the flat index of each
        segment's last real token. Last stage returns logits
        (1, n_out, V); others the full hidden (1, T, d)."""
        out, self.cache = self._ragged_fn(self.params, x_in, positions,
                                          row, valid, tables, out_idx,
                                          self.cache)
        return out

    def prefill_slot(self, x_in, slot: int, positions, prefix_embeds=None,
                     block_tables=None, hist_len: int = 0):
        """Prefill one request (batch 1 inputs) into cache slot `slot`.
        Recurrent states start from zero (fresh cache), then results are
        scattered into the live batched cache. Paged attention KV is
        written straight into the shared page pool at the blocks named by
        ``block_tables`` (1, nb). ``hist_len > 0`` (paged, attention-only
        models) marks x_in as a chunk continuing a sequence whose first
        ``hist_len`` rows already live in the pool.

        ``hist_len`` is a static jit argument, so each distinct
        (chunk_len, hist_len) pair compiles once — fine at smoke scale
        where chunk shapes recur; a production port would pad chunks to a
        fixed size and mask via kv_len to keep one executable."""
        if hist_len != 0 and not self.paged:
            raise ValueError("chunked prefill requires the paged layout")
        p0, p1 = self.periods
        dt = jnp.dtype(self.cfg.dtype)
        # in paged mode only the recurrent slots start fresh at batch 1
        # (n_pages=1 keeps the throwaway attn pools tiny); attn slots
        # compute against the live shared pools
        fresh = transformer.init_cache(
            self.cfg, 1, self.max_seq, dt, n_periods=p1 - p0,
            paged=self.paged, n_pages=1 if self.paged else None,
            page_size=1 if self.paged else None)
        if self.paged:
            fresh = {name: (self.cache[name] if "k_pages" in self.cache[name]
                            else fresh[name])
                     for name in self.cache}
        out, one_cache = self._prefill_fn(self.params, x_in, positions,
                                          fresh, block_tables, prefix_embeds,
                                          with_prefix=prefix_embeds is not None,
                                          hist_len=hist_len)

        def scatter(full, one):
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype),
                (0, slot) + (0,) * (full.ndim - 2))

        if self.paged:
            merged = {}
            for name, sub in one_cache.items():
                if "k_pages" in sub:      # pool already updated in-place
                    merged[name] = sub
                else:
                    merged[name] = jax.tree.map(scatter, self.cache[name],
                                                sub)
            self.cache = merged
        else:
            self.cache = jax.tree.map(scatter, self.cache, one_cache)
        return out

    def decode(self, x_in, positions, block_tables=None):
        out, self.cache = self._decode_fn(self.params, x_in, positions,
                                          self.cache, block_tables)
        return out

    def copy_pages(self, src: int, dst: int):
        """Copy page ``src`` onto page ``dst`` in every attention pool
        (all periods) — the engine's copy-on-write when a prefix-cache
        hit covers a whole prompt and the final token must be recomputed
        into a private block. The functional ``.at[].set`` rebuilds each
        pool array; acceptable for the occasional full-prompt hit at
        smoke scale (a production port would batch pending copies into
        one donated scatter)."""
        if self.tracer is not None:
            self.tracer.on_copy_pages(src, dst, self.stage)

        def cp(a):
            return a.at[:, dst].set(a[:, src])

        self.cache = {name: ({leaf: cp(arr) for leaf, arr in sub.items()}
                             if "k_pages" in sub else sub)
                      for name, sub in self.cache.items()}

    def read_page(self, name: str, blk: int):
        """Host copies of one attention pool's page ``blk``, every leaf:
        {"k_pages": (P_stage, page_size, Hkv, hd), "v_pages": ..., plus
        scale/zero leaves (P_stage, page_size, Hkv) for int8 pools}. Used
        by the KV spill hook at eviction time, while the page content is
        intact."""
        if self.tracer is not None:
            self.tracer.on_page_read(name, blk, self.stage)
        sub = self.cache[name]
        return {leaf: np.asarray(arr[:, blk]) for leaf, arr in sub.items()}

    def write_page(self, name: str, blk: int, k, v, extras=None):
        """Write one page's K/V (and, for int8 pools, the scale/zero
        ``extras`` dict) back into an attention pool — the restore half of
        the HBM → host KV spill (router/kvtier.py). Preserves every other
        pool leaf."""
        if self.tracer is not None:
            self.tracer.on_page_write(name, blk, self.stage)
        sub = dict(self.cache[name])
        sub["k_pages"] = sub["k_pages"].at[:, blk].set(
            jnp.asarray(k, sub["k_pages"].dtype))
        sub["v_pages"] = sub["v_pages"].at[:, blk].set(
            jnp.asarray(v, sub["v_pages"].dtype))
        for leaf, arr in (extras or {}).items():
            sub[leaf] = sub[leaf].at[:, blk].set(
                jnp.asarray(arr, sub[leaf].dtype))
        self.cache[name] = sub

    def retire(self):
        """Drop the cache and params so a retired engine's stale worker
        fails fast instead of writing into pools it no longer owns."""
        self.cache = None
        self.params = None

    def clear_slot(self, slot: int):
        """Zero a slot's recurrent state (attn KV needs no clear: contiguous
        caches are masked by kv_len; paged pools are unreachable once the
        block table row is freed)."""

        def clr(a):
            return a.at[:, slot].set(jnp.zeros_like(a[:, slot]))

        self.cache = {name: (sub if "k_pages" in sub
                             else jax.tree.map(clr, sub))
                      for name, sub in self.cache.items()}
