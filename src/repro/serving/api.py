"""Request-lifecycle types for the serving API (§6.2 endpoint abstraction).

Everything a caller needs to drive a generation without reaching into the
engine: ``SamplingParams`` describe *how* to decode, ``TokenEvent`` /
``StepOutput`` stream *what* was decoded, ``FinishReason`` says *why* a
request stopped, and ``RequestMetrics`` records the per-request lifecycle
in scheduler steps (the engine's time unit — wall-clock belongs to the
benchmarks).

Determinism contract: :func:`sample_token` keys its PRNG only on
``(seed, token_index)``, never on batch position, slot, KV layout, or
engine identity — so a request's token stream survives continuous-batching
reshuffles and §6.2 consolidation bit-exactly, and ``temperature=0``
reduces to the plain ``argmax`` the pre-lifecycle engine used.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import SLO


class FinishReason(str, enum.Enum):
    LENGTH = "length"            # hit SamplingParams.max_new
    EOS = "eos"                  # emitted SamplingParams.eos_token
    STOP_TOKEN = "stop_token"    # emitted one of SamplingParams.stop_tokens


@dataclass(frozen=True)
class SamplingParams:
    """Decode policy for one request. The default is greedy argmax with
    length-only termination — the legacy engine behaviour, bit-exact.

    ``priority`` and ``slo`` are *scheduling* hints, consumed by the
    engine's ``SchedulingPolicy`` (serving/scheduler.py): priority is an
    integer where larger means more important (the priority policy admits
    high before low and may preempt low for high); ``slo`` carries
    per-request TTFT/TPOT budgets, interpreted in **scheduler steps** by
    the SLO-deadline (EDF) policy. Both are ignored by the default FCFS
    policy, so plain requests behave exactly as before.
    """
    max_new: int = 16
    temperature: float = 0.0     # <= 0 means greedy argmax
    top_k: int = 0               # 0 means the full vocab
    seed: int = 0                # PRNG seed for temperature > 0
    eos_token: Optional[int] = None
    stop_tokens: Tuple[int, ...] = ()
    priority: int = 0            # scheduling priority (higher wins)
    slo: Optional[SLO] = None    # TTFT/TPOT budgets in scheduler steps

    def __post_init__(self):
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclass
class RequestMetrics:
    """Lifecycle counters in scheduler steps.

    ``ttft_steps`` is submit -> first token (1 for a request admitted at
    the very next step); ``queue_steps`` is the waiting part of that TTFT
    (deferred admission, plus prefill-chunk steps under chunked prefill);
    ``tpot_steps`` is the decode-steps-per-generated-token proxy (1.0
    when the request decoded every step it was resident);
    ``cached_tokens`` is the prompt prefix served from the paged prefix
    cache — tokens whose KV was reused instead of recomputed (on a
    preempted request it is refreshed at re-admission, so it also shows
    how much of the resume was served from the retained prefix blocks);
    ``preemptions`` counts how many times the scheduler evicted this
    request from its slot to make room for higher-value work.
    ``restored_tokens`` is the part of ``cached_tokens`` that was not in
    HBM at admission but restored from a lower KV tier
    (router/kvtier.py); ``restore_seconds`` is the modeled wall time of
    those transfers on the contention-fair ``FetchSchedule``.
    """
    submit_step: int = 0
    admit_step: Optional[int] = None      # step of the first token
    finish_step: Optional[int] = None
    decode_steps: int = 0                 # decode passes it took part in
    n_tokens: int = 0                     # tokens emitted so far
    cached_tokens: int = 0                # prompt tokens hit in prefix cache
    restored_tokens: int = 0              # ...restored from a lower KV tier
    restore_seconds: float = 0.0          # modeled restore transfer time
    preemptions: int = 0                  # times evicted from a slot
    last_token_step: Optional[int] = None  # step of the latest token

    @property
    def ttft_steps(self) -> Optional[int]:
        if self.admit_step is None:
            return None
        return self.admit_step - self.submit_step

    @property
    def queue_steps(self) -> Optional[int]:
        ttft = self.ttft_steps
        return None if ttft is None else ttft - 1

    @property
    def tpot_steps(self) -> Optional[float]:
        if self.n_tokens <= 1:
            return None
        return self.decode_steps / (self.n_tokens - 1)


@dataclass(frozen=True)
class TokenEvent:
    """One newly emitted token. ``finish_reason`` is set on a request's
    final token (the token itself is still part of the output)."""
    rid: int
    token: int
    finish_reason: Optional[FinishReason] = None


@dataclass(frozen=True)
class StepOutput:
    """What one ``Engine.step()`` produced, in emission order: prefill
    tokens of newly admitted requests first (admission order), then one
    decode token per resident request (slot order). Under chunked prefill
    a step can make prefill progress without emitting a prefill token —
    ``prefill_tokens`` counts the prompt tokens computed this step, so a
    mixed step shows both ``prefill_tokens > 0`` and decode events.
    ``preempted`` lists the requests the scheduler evicted this step;
    they re-enter the admission queue and resume later (no events are
    emitted for a preemption — the stream just pauses)."""
    step: int
    events: Tuple[TokenEvent, ...]
    finished: Tuple[int, ...]             # rids that finished this step
    num_active: int                       # residents after the step
    num_queued: int                       # waiting + preempted, pre-admission
    prefill_tokens: int = 0               # prompt tokens prefilled this step
    preempted: Tuple[int, ...] = ()       # rids preempted this step


@dataclass(frozen=True)
class RequestOutput:
    """Immutable summary of a finished (or in-flight) request."""
    rid: int
    prompt: Tuple[int, ...]
    token_ids: Tuple[int, ...]
    finish_reason: Optional[FinishReason]
    metrics: RequestMetrics

    @property
    def done(self) -> bool:
        return self.finish_reason is not None


def sample_token(logits, params: SamplingParams, token_index: int) -> int:
    """Pick the next token from 1-D ``logits``.

    Greedy (``temperature <= 0``) is plain ``argmax`` — bit-exact with the
    pre-lifecycle engine. Otherwise: temperature-scaled, optionally top-k
    truncated, seeded categorical whose key depends only on
    ``(params.seed, token_index)`` (see module docstring).
    """
    if params.greedy:
        return int(jnp.argmax(logits))
    scaled = jnp.asarray(logits, jnp.float32) / params.temperature
    if params.top_k and params.top_k < scaled.shape[-1]:
        kth = jax.lax.top_k(scaled, params.top_k)[0][-1]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    key = jax.random.fold_in(jax.random.PRNGKey(params.seed), token_index)
    return int(jax.random.categorical(key, scaled))
