"""End-to-end serverless LLM serving simulation.

Runs the paper's three systems over the same cluster / workload:

  * ``hydra``          — ParaServe/HydraServe: Alg.1 + Alg.2 + worker-level
                         overlapping + pipeline consolidation (+cache opt).
  * ``vllm``           — serverless vLLM baseline: single worker, first-fit
                         placement, fully sequential cold-start stages.
  * ``serverlessllm``  — pre-created containers, host-memory model cache with
                         loading-optimized checkpoints, locality placement.

Compute latencies use the paper's own predictor terms (t_p scaled by prompt
length, t_d per token, t_n per pipeline hop); fetch times come from the
contention-aware fair-share NIC fluid model in cluster/cluster.py.
Worker failures can be injected; recovery is a fresh (pipeline-parallel)
cold start — see DESIGN.md §7.

All *scaling decisions* — when to launch, how many groups, how long an
idle worker survives, when to prewarm a reaped model, which models to
proactively distribute — come from the shared ``FleetController``
(repro/fleet/controller.py), the same policy object the real-JAX
``FleetFrontend`` drives; this simulation is only a data plane executing
its decisions on the discrete-event clock.
"""

from __future__ import annotations

import collections
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.cluster import Cluster, Flow
from repro.cluster.sim import EventSim
from repro.core.coldstart import OverlapFlags
from repro.core.controller import CentralController
from repro.core.parallelism import NoPlacement
from repro.core.types import GB, ColdStartScheme, ModelProfile, ServerSpec
from repro.fleet.controller import (FleetController, FleetPolicy,
                                    LaunchPlan, PlacementAction)
from repro.workloads.generator import ModelInstance, Request

BG_FETCH_WEIGHT = 0.5                # background (consolidation) fetch priority
PLACEMENT_FETCH_WEIGHT = 0.1         # proactive-distribution seeding priority


@dataclass
class Worker:
    wid: str
    model: str
    base_model: str
    server_id: str
    device: object
    hbm: int
    full_memory: bool
    state: str = "cold"              # cold|pipeline|standalone|dead
    stage: int = 0
    group: Optional["Group"] = None
    ready_time: Optional[float] = None
    active: List[Request] = field(default_factory=list)
    keepalive_ev: object = None
    bg_flow: Optional[Flow] = None
    bg_done: bool = False
    fetch_flow: Optional[Flow] = None


@dataclass
class Group:
    gid: int
    model: str
    scheme: ColdStartScheme
    workers: List[Worker]
    mode: str                        # consolidation mode: 'down'|'up'|'none'
    t0: float = 0.0                  # launch instant
    reason: str = "demand"           # demand | prewarm
    ready: bool = False
    dissolved: bool = False
    active: List[Request] = field(default_factory=list)
    keepalive_ev: object = None

    @property
    def s(self):
        return self.scheme.s

    @property
    def w(self):
        return self.scheme.w


class ServerlessSim:
    def __init__(self, servers: Sequence[ServerSpec],
                 profiles: Dict[str, ModelProfile],
                 instances: Sequence[ModelInstance],
                 system: str = "hydra",
                 cache_enabled: bool = False,
                 flags: Optional[OverlapFlags] = None,
                 max_batch: int = 8,
                 keepalive_s: float = 300.0,
                 consolidate: bool = True,
                 force_s: Optional[int] = None,
                 host_mem_bytes: int = 188 * GB,
                 stage_bytes_fn: Optional[Callable] = None,
                 policy: Optional[FleetPolicy] = None):
        assert system in ("hydra", "vllm", "serverlessllm")
        self.system = system
        self.cache_enabled = cache_enabled or system == "serverlessllm"
        self.sim = EventSim()
        self.cluster = Cluster(self.sim, list(servers), host_mem_bytes)
        self.controller = CentralController(
            {s.server_id: s for s in servers},
            per_worker_capacity=max_batch,
            overlapped=(system == "hydra"))
        # the one scaling-policy implementation, shared with the real
        # FleetFrontend; ``keepalive_s`` remains the naive-policy shorthand
        self.fleet = FleetController(
            self.controller, policy or FleetPolicy(keepalive_s=keepalive_s))
        self.max_batch = max_batch
        self.consolidate = consolidate and system == "hydra"
        self.force_s = force_s
        self.stage_bytes_fn = stage_bytes_fn

        if flags is not None:
            self.flags = flags
        elif system == "hydra":
            self.flags = OverlapFlags.all()
        else:
            self.flags = OverlapFlags.none()

        for name, prof in profiles.items():
            if prof.kv_bytes_per_token is None:
                raise ValueError(
                    f"profile {name!r} has no kv_bytes_per_token: KV"
                    " migration accounting needs the real geometry — set"
                    " ModelProfile.kv_bytes_per_token (see"
                    " ModelProfile.kv_bytes_from_geometry or"
                    " workloads.applications.kv_bytes_for)")

        self.instances = {i.name: i for i in instances}
        # every instance is its own model in the registry (its bytes must be
        # fetched separately), sharing the base model's timing profile
        for inst in instances:
            base = profiles[inst.base_model]
            self.controller.register_model(ModelProfile(
                name=inst.name, size_bytes=base.size_bytes,
                timings=base.timings,
                slo=type(base.slo)(inst.slo_ttft, inst.slo_tpot),
                max_pp=1 if system != "hydra" else base.max_pp,
                full_hbm_bytes=base.full_hbm_bytes,
                kv_bytes_per_token=base.kv_bytes_per_token))

        self.queues: Dict[str, collections.deque] = collections.defaultdict(
            collections.deque)
        self.warm_workers: Dict[str, List[Worker]] = collections.defaultdict(list)
        self.groups: Dict[str, List[Group]] = collections.defaultdict(list)
        self.provisioning: Dict[str, int] = collections.defaultdict(int)

        self._wid = itertools.count()
        self._gid = itertools.count()
        self.finished: List[Request] = []
        self.cold_start_log: List[dict] = []
        self.placement_log: List[dict] = []
        self.failures_injected = 0
        self._retry_pending: set = set()
        self._pulse_armed = False
        self._pulse_until = 0.0

    # ================================================================ util
    def _profile(self, model: str) -> ModelProfile:
        return self.controller.models[model]

    def _prefill_time(self, model: str, prompt_tokens: int, s: int, w: int
                      ) -> float:
        t = self._profile(model).timings
        base = t.t_p * (prompt_tokens / 1024.0)
        if s <= 1:
            return base
        return base * (s - w + w / s) + t.t_n * s

    def _tpot(self, model: str, s: int, w: int) -> float:
        t = self._profile(model).timings
        if s <= 1:
            return t.t_d
        return t.t_d * (s - w + w / s) + t.t_n * s

    def _kv_bytes_per_token(self, model: str) -> int:
        """Per-model KV footprint; registration guarantees the geometry."""
        return self._profile(model).kv_bytes_per_token

    # ============================================================ requests
    def submit(self, requests: Sequence[Request]):
        for r in requests:
            self.sim.at(r.arrival, lambda r=r: self._arrive(r))

    def run(self, until: Optional[float] = None):
        pol = self.fleet.policy
        if until is not None and (pol.prewarm or pol.proactive_placement):
            self._arm_pulses(until)
        self.sim.run(until=until)

    # ------------------------------------------------------- control pulses
    def _arm_pulses(self, until: float):
        """Run the fleet control loop (placement rounds + prewarm checks)
        at the policy's pulse cadence for the span of this ``run`` — the
        sim's twin of ``FleetFrontend.advance``."""
        self._pulse_until = max(self._pulse_until, until)
        if self._pulse_armed:
            return
        pulse = max(self.fleet.policy.pulse_s, 1e-3)

        def tick():
            self._control_tick()
            if self.sim.now + pulse <= self._pulse_until:
                self.sim.after(pulse, tick)
            else:
                self._pulse_armed = False

        self._pulse_armed = True
        self.sim.after(pulse, tick)

    def _control_tick(self):
        now = self.sim.now
        for act in self.fleet.placement_round(now):
            self._seed_placement(act)
        for plan in self.fleet.prewarm_due(now, self._at_zero):
            self._execute_plan(plan.model, plan)

    def _at_zero(self, model: str) -> bool:
        return (not self.warm_workers[model] and not self.groups[model]
                and not self.queues[model]
                and self.provisioning[model] == 0)

    def _seed_placement(self, act: PlacementAction):
        """Execute one Alg. 1 proactive-distribution action: background-
        fetch the model's bytes into the target server's host cache (low
        priority on the NIC), so a later cold start there skips the
        network fetch entirely."""
        server = self.cluster.servers[act.server_id]
        if server.cache_has(act.model):
            return
        prof = self._profile(act.model)
        self.placement_log.append({"model": act.model,
                                   "server": act.server_id,
                                   "t": self.sim.now})
        self.cluster.start_fetch(
            act.server_id, prof.size_bytes,
            lambda: server.cache_put(act.model, prof.size_bytes),
            weight=PLACEMENT_FETCH_WEIGHT)

    def _arrive(self, req: Request):
        self.fleet.record_arrival(req.model, self.sim.now)
        req.cold = not (self.warm_workers[req.model]
                        or any(g.ready and not g.dissolved
                               for g in self.groups[req.model]))
        self.queues[req.model].append(req)
        self._drain(req.model)
        self._maybe_cold_start(req.model)

    def _drain(self, model: str):
        """Assign queued requests to endpoints with spare capacity."""
        q = self.queues[model]
        if not q:
            return
        for wkr in list(self.warm_workers[model]):
            while q and len(wkr.active) < self.max_batch:
                self._start_on_worker(wkr, q.popleft())
        for grp in self.groups[model]:
            if not grp.ready or grp.dissolved:
                continue
            while q and len(grp.active) < self.max_batch:
                self._start_on_group(grp, q.popleft())

    # ------------------------------------------------------------- serving
    def _start_on_worker(self, wkr: Worker, req: Request):
        wkr.active.append(req)
        self._cancel_keepalive(wkr)
        pf = self._prefill_time(req.model, req.prompt_tokens, 1, 1)
        first = self.sim.now + pf
        req.first_token = first
        tpot = self._tpot(req.model, 1, 1)
        dur = pf + max(req.output_tokens - 1, 0) * tpot
        req._rate = tpot                     # type: ignore[attr-defined]
        req._holder = wkr                    # type: ignore[attr-defined]
        req._done_ev = self.sim.after(       # type: ignore[attr-defined]
            dur, lambda: self._complete_on_worker(wkr, req))

    def _complete_on_worker(self, wkr: Worker, req: Request):
        if req in wkr.active:
            wkr.active.remove(req)
        req.completion = self.sim.now
        self.finished.append(req)
        self._drain(req.model)
        if not wkr.active:
            self._arm_keepalive(wkr)

    def _start_on_group(self, grp: Group, req: Request):
        grp.active.append(req)
        self._cancel_group_keepalive(grp)
        pf = self._prefill_time(req.model, req.prompt_tokens, grp.s, grp.w)
        req.first_token = self.sim.now + pf
        tpot = self._tpot(req.model, grp.s, grp.w)
        req._rate = tpot                     # type: ignore[attr-defined]
        req._holder = grp                    # type: ignore[attr-defined]
        dur = pf + max(req.output_tokens - 1, 0) * tpot
        req._done_ev = self.sim.after(       # type: ignore[attr-defined]
            dur, lambda: self._complete_on_group(grp, req))

    def _complete_on_group(self, grp: Group, req: Request):
        if req in grp.active:
            grp.active.remove(req)
        req.completion = self.sim.now
        self.finished.append(req)
        self._drain(req.model)
        if not grp.active and not grp.dissolved:
            self._arm_group_keepalive(grp)

    # ----------------------------------------------------------- keepalive
    def _arm_keepalive(self, wkr: Worker):
        self._cancel_keepalive(wkr)
        wkr.keepalive_ev = self.sim.after(
            self.fleet.keepalive(wkr.model, self.sim.now),
            lambda: self._terminate_worker(wkr))

    def _cancel_keepalive(self, wkr: Worker):
        if wkr.keepalive_ev is not None:
            self.sim.cancel(wkr.keepalive_ev)
            wkr.keepalive_ev = None

    def _arm_group_keepalive(self, grp: Group):
        self._cancel_group_keepalive(grp)
        grp.keepalive_ev = self.sim.after(
            self.fleet.keepalive(grp.model, self.sim.now),
            lambda: self._terminate_group(grp))

    def _cancel_group_keepalive(self, grp: Group):
        if grp.keepalive_ev is not None:
            self.sim.cancel(grp.keepalive_ev)
            grp.keepalive_ev = None

    def _terminate_worker(self, wkr: Worker):
        if wkr.active or wkr.state == "dead":
            return
        wkr.state = "dead"
        server = self.cluster.servers[wkr.server_id]
        server.free(wkr.device, wkr.hbm)
        if wkr in self.warm_workers[wkr.model]:
            self.warm_workers[wkr.model].remove(wkr)

    def _terminate_group(self, grp: Group):
        if grp.active or grp.dissolved:
            return
        grp.dissolved = True
        for wkr in grp.workers:
            if wkr.bg_flow is not None and not wkr.bg_flow.done:
                self.cluster.cancel_fetch(wkr.bg_flow)
            wkr.active = []
            self._terminate_worker(wkr)
        if grp in self.groups[grp.model]:
            self.groups[grp.model].remove(grp)

    # ========================================================== cold start
    def _capacity_in_flight(self, model: str) -> int:
        cap = 0
        for wkr in self.warm_workers[model]:
            cap += self.max_batch - len(wkr.active)
        for grp in self.groups[model]:
            if not grp.dissolved:
                cap += self.max_batch - len(grp.active)
        cap += self.provisioning[model] * self.max_batch
        return cap

    def _maybe_cold_start(self, model: str):
        current = len(self.warm_workers[model]) + sum(
            1 for g in self.groups[model] if not g.dissolved)
        plan = self.fleet.cold_start_plan(
            model, len(self.queues[model]),
            self._capacity_in_flight(model), current, self.sim.now)
        if plan:
            self._execute_plan(model, plan)

    def _execute_plan(self, model: str, plan: LaunchPlan):
        """Run one FleetController launch decision against the data plane
        (with HBM-pressure eviction + retry on placement failure)."""
        try:
            self._launch_plan(model, plan)
        except NoPlacement:
            if not self._evict_idle():
                self._schedule_retry(model)
                return
            try:
                self._launch_plan(model, plan)
            except NoPlacement:
                self._schedule_retry(model)

    def _launch_plan(self, model: str, plan: LaunchPlan):
        now = self.sim.now
        if self.system != "hydra":
            prof = self._profile(model)
            sid = self._place_single(model, prof)
            if sid is None:
                raise NoPlacement(model)
            scheme = ColdStartScheme(1, 1, (sid,), 0.0, prof.timings.t_d,
                                     False)
            self._launch_group(model, scheme, "none", reason=plan.reason)
            return
        mode = plan.mode if self.consolidate else "none"
        # with consolidation off the data plane can't run scale-up groups;
        # cap the fleet's burst sizing at one group (old behaviour)
        n_groups = plan.n_groups if self.consolidate else 1
        for _ in range(n_groups):
            scheme = self.controller.plan_cold_start(
                model, self.cluster.free_hbm(), now, force_s=self.force_s,
                prefer=self.fleet.preferred_servers(model))
            self._launch_group(model, scheme, mode, reason=plan.reason)

    def _evict_idle(self) -> bool:
        """HBM pressure relief: terminate one idle warm worker (LRU-ish) or
        one idle group so a queued model can cold-start."""
        for model, workers in self.warm_workers.items():
            for wkr in workers:
                if not wkr.active and not self.queues[model]:
                    self._cancel_keepalive(wkr)
                    self._terminate_worker(wkr)
                    return True
        for model, groups in self.groups.items():
            for grp in groups:
                if grp.ready and not grp.active and not self.queues[model]:
                    self._cancel_group_keepalive(grp)
                    self._terminate_group(grp)
                    return True
        return False

    def _schedule_retry(self, model: str):
        if model in self._retry_pending:
            return
        self._retry_pending.add(model)

        def retry():
            self._retry_pending.discard(model)
            self._maybe_cold_start(model)

        self.sim.after(1.0, retry)

    # --------------------------------------------------------------- launch
    def _launch_group(self, model: str, scheme: ColdStartScheme, mode: str,
                      reason: str = "demand"):
        now = self.sim.now
        prof = self._profile(model)
        gid = next(self._gid)
        workers: List[Worker] = []
        stage_bytes = self._stage_bytes(model, scheme.s)
        for i, sid in enumerate(scheme.servers):
            full = i < scheme.w
            need = prof.hbm_full() if full else prof.hbm_low(scheme.s)
            server = self.cluster.servers[sid]
            dev = server.fit_device(need)
            if dev is None:          # raced out of memory — retry smaller
                need = prof.hbm_low(scheme.s)
                dev = server.fit_device(need)
                if dev is None:
                    continue
                full = False
            server.alloc(dev, need)
            wkr = Worker(wid=f"w{next(self._wid)}", model=model,
                         base_model=self.instances[model].base_model,
                         server_id=sid, device=dev, hbm=need,
                         full_memory=full, stage=i)
            workers.append(wkr)
        if not workers:
            self._schedule_retry(model)
            return
        grp = Group(gid, model, scheme, workers, mode, t0=now,
                    reason=reason)
        for wkr in workers:
            wkr.group = grp
        self.groups[model].append(grp)
        self.provisioning[model] += 1

        worker_ids = [w.wid for w in workers]
        self.controller.admit_fetches(model, scheme, worker_ids,
                                      stage_bytes[: len(workers)], now)
        t = prof.timings
        pending = {"n": len(workers)}
        t0 = now

        for wkr, nbytes in zip(workers, stage_bytes):
            self._provision_worker(wkr, nbytes, t, t0, pending, grp)

    def _stage_bytes(self, model: str, s: int) -> List[int]:
        prof = self._profile(model)
        if self.stage_bytes_fn is not None:
            return [self.stage_bytes_fn(self.instances[model].base_model,
                                        s, i) for i in range(s)]
        return [prof.size_bytes // s] * s

    def _provision_worker(self, wkr: Worker, nbytes: int, t, t0: float,
                          pending: dict, grp: Group):
        """Run the worker-level overlapped cold-start stages with the
        contention-accurate fetch (see core/coldstart.py for the analytic
        twin of this logic)."""
        server = self.cluster.servers[wkr.server_id]
        flags = self.flags
        # a host-cache hit skips the network fetch — populated either by
        # the serverlessllm-style cache or by Alg. 1 proactive placement
        cached = (self.cache_enabled
                  or self.fleet.policy.proactive_placement) \
            and server.cache_has(wkr.model)
        load_seconds = nbytes / server.spec.pcie_bytes_per_s

        if flags.overlap_load:
            runtime_end = t0 + t.t_cc + t.t_cu
            lib_end = runtime_end + t.t_l
        else:
            lib_end = t0 + t.t_cc + t.t_l
            runtime_end = lib_end + t.t_cu

        if self.system == "serverlessllm":
            # containers pre-created, libraries resident
            runtime_end = t0 + t.t_cu
            lib_end = runtime_end

        def after_fetch(fetch_end: float):
            if self.cache_enabled:
                server.cache_put(wkr.model, int(nbytes))
            load_begin = max(runtime_end, t0 if flags.prefetch else fetch_end)
            if flags.stream:
                load_end = max(fetch_end, load_begin + load_seconds)
            else:
                load_end = max(fetch_end, load_begin) + load_seconds
            ready = max(load_end, lib_end)
            self.controller.fetch_complete(wkr.server_id, wkr.wid,
                                           self.sim.now)
            self.sim.at(ready, lambda: self._worker_ready(wkr, grp, pending,
                                                          ready))

        if cached:
            # host cache hit: no network fetch, load from host memory
            self.sim.at(max(runtime_end, t0),
                        lambda: after_fetch(self.sim.now))
            server.cache_touch(wkr.model)
            return

        fetch_start = t0 if flags.prefetch else runtime_end
        if self.system == "serverlessllm":
            fetch_start = runtime_end

        def start_flow():
            wkr.fetch_flow = self.cluster.start_fetch(
                wkr.server_id, nbytes,
                lambda: after_fetch(self.sim.now))

        self.sim.at(fetch_start, start_flow)

    def _worker_ready(self, wkr: Worker, grp: Group, pending: dict,
                      ready: float):
        if wkr.state == "dead":
            return
        wkr.state = "pipeline" if grp.scheme.s > 1 else "standalone"
        wkr.ready_time = ready
        pending["n"] -= 1
        if pending["n"] == 0:
            self._group_ready(grp)

    def _group_ready(self, grp: Group):
        grp.ready = True
        self.provisioning[grp.model] -= 1
        self.cold_start_log.append({
            "model": grp.model, "s": grp.s, "w": grp.w,
            "t0": grp.t0, "ready": self.sim.now,
            "duration": self.sim.now - grp.t0,
            "reason": grp.reason,
            "predicted_ttft": grp.scheme.predicted_ttft,
        })
        if grp.s == 1:
            # single worker: promote immediately to the warm pool
            wkr = grp.workers[0]
            wkr.state = "standalone"
            wkr.group = None
            self.warm_workers[grp.model].append(wkr)
            grp.dissolved = True
            self.groups[grp.model].remove(grp)
            self._drain(grp.model)
            if not wkr.active:
                self._arm_keepalive(wkr)
            return
        self._drain(grp.model)
        if self.consolidate and grp.mode in ("down", "up"):
            self._start_consolidation(grp)
        if not grp.active:
            self._arm_group_keepalive(grp)

    # ====================================================== consolidation
    def _start_consolidation(self, grp: Group):
        prof = self._profile(grp.model)
        total = prof.size_bytes
        stage_bytes = self._stage_bytes(grp.model, grp.s)
        if grp.mode == "up":
            targets = grp.workers
        else:
            # scale-down: the target must be upgradable to full memory
            targets = [w for w in grp.workers
                       if w.full_memory
                       or w.device.hbm_free >= prof.hbm_full() - w.hbm][:1]
        for wkr in targets:
            rest = total - stage_bytes[min(wkr.stage, len(stage_bytes) - 1)]
            server = self.cluster.servers[wkr.server_id]
            # upgrade a low-memory worker's reservation to full
            if not wkr.full_memory:
                extra = prof.hbm_full() - wkr.hbm
                if wkr.device.hbm_free >= extra:
                    server.alloc(wkr.device, extra)
                    wkr.hbm += extra
                    wkr.full_memory = True
                else:
                    continue        # cannot upgrade now; stay in pipeline
            wkr.bg_flow = self.cluster.start_fetch(
                wkr.server_id, rest,
                lambda wkr=wkr: self._bg_fetch_done(grp, wkr),
                weight=BG_FETCH_WEIGHT)

    def _bg_fetch_done(self, grp: Group, wkr: Worker):
        wkr.bg_done = True
        if grp.dissolved:
            return
        if grp.mode == "down":
            self._consolidate_down(grp, wkr)
        else:
            if all(w.bg_done or not w.full_memory for w in grp.workers):
                self._consolidate_up(grp)

    def _migration_seconds(self, grp: Group) -> float:
        kv_bytes = sum(r.prompt_tokens + self._tokens_done(r)
                       for r in grp.active) \
            * self._kv_bytes_per_token(grp.model)
        # gathered over (s-1) source workers in parallel, streamed
        bw = min(self.cluster.servers[w.server_id].spec.nic_bytes_per_s
                 for w in grp.workers)
        frac = (grp.s - 1) / grp.s
        return 0.02 + kv_bytes * frac / bw

    def _tokens_done(self, req: Request) -> int:
        if req.first_token is None or self.sim.now <= req.first_token:
            return 0
        rate = getattr(req, "_rate", None) or 1e9
        return min(int((self.sim.now - req.first_token) / rate) + 1,
                   req.output_tokens)

    def _consolidate_down(self, grp: Group, wkr: Worker):
        """Migrate KV to `wkr`, retime ongoing requests at standalone rate,
        terminate the other stages (Fig. 4(c) / Fig. 13)."""
        mig = self._migration_seconds(grp)

        def finish():
            if grp.dissolved:
                return
            grp.dissolved = True
            now = self.sim.now
            for req in list(grp.active):
                self._retime(req, wkr, now)
            wkr.active = list(grp.active)
            grp.active = []
            wkr.state = "standalone"
            wkr.group = None
            self.warm_workers[grp.model].append(wkr)
            for other in grp.workers:
                if other is not wkr:
                    other.active = []
                    self._terminate_worker(other)
            if grp in self.groups[grp.model]:
                self.groups[grp.model].remove(grp)
            self._drain(grp.model)
            if not wkr.active:
                self._arm_keepalive(wkr)

        self.sim.after(mig, finish)

    def _consolidate_up(self, grp: Group):
        """Every stage becomes a standalone replica (Fig. 4(d) / Fig. 7)."""
        if grp.dissolved:
            return
        grp.dissolved = True
        now = self.sim.now
        first = grp.workers[0]
        mig = self._migration_seconds(grp)
        for req in list(grp.active):
            self._retime(req, first, now + mig)
        first.active = list(grp.active)
        grp.active = []
        for wkr in grp.workers:
            if not wkr.bg_done:     # couldn't upgrade: terminate
                wkr.active = []
                self._terminate_worker(wkr)
                continue
            wkr.state = "standalone"
            wkr.group = None
            self.warm_workers[grp.model].append(wkr)
            if not wkr.active:
                self._arm_keepalive(wkr)
        if grp in self.groups[grp.model]:
            self.groups[grp.model].remove(grp)
        self._drain(grp.model)

    def _retime(self, req: Request, wkr: Worker, effective_at: float):
        """Re-schedule a request's completion at the standalone decode rate
        from `effective_at` on (KV already migrated)."""
        ev = getattr(req, "_done_ev", None)
        if ev is not None:
            self.sim.cancel(ev)
        done = self._tokens_done(req)
        remaining = max(req.output_tokens - done, 0)
        new_rate = self._tpot(req.model, 1, 1)
        finish_at = max(effective_at, self.sim.now) + remaining * new_rate
        # effective tpot improves from the migration point (Fig. 13)
        req._rate = new_rate                  # type: ignore[attr-defined]
        req._holder = wkr                     # type: ignore[attr-defined]
        req._done_ev = self.sim.at(           # type: ignore[attr-defined]
            finish_at, lambda: self._complete_on_worker(wkr, req))

    # ============================================================ baseline
    def _place_single(self, model: str, prof: ModelProfile) -> Optional[str]:
        servers = self.cluster.servers
        if self.system == "serverlessllm":
            for sid, s in servers.items():
                if s.cache_has(model) and s.fit_device(prof.hbm_full()):
                    return sid
        for sid, s in servers.items():       # first-fit (serverless vLLM)
            if s.fit_device(prof.hbm_full()):
                return sid
        return None

    # ============================================================ failures
    def inject_failure(self, model: str):
        """Kill one running worker of `model`; requests are re-queued and a
        fresh cold start is triggered (recovery path == cold-start path)."""
        victims = self.warm_workers[model] or [
            w for g in self.groups[model] for w in g.workers]
        if not victims:
            return False
        wkr = victims[0]
        self.failures_injected += 1
        requeue = list(wkr.active)
        if wkr.group is not None:
            grp = wkr.group
            requeue = list(grp.active)
            for r in requeue:
                ev = getattr(r, "_done_ev", None)
                self.sim.cancel(ev)
                r.first_token = None
            grp.active = []
            self._terminate_group(grp)
        else:
            for r in requeue:
                ev = getattr(r, "_done_ev", None)
                self.sim.cancel(ev)
                r.first_token = None
            wkr.active = []
            self._terminate_worker(wkr)
        for r in requeue:
            self.queues[model].appendleft(r)
        self._maybe_cold_start(model)
        return True

    # ============================================================= metrics
    def metrics(self) -> dict:
        done = self.finished
        if not done:
            return {"n": 0}
        ttft_ok = sum(1 for r in done if r.ttft_ok())
        tpot_ok = sum(1 for r in done if r.tpot_ok())
        ttfts = sorted(r.ttft for r in done)
        cold_ttfts = sorted(r.ttft for r in done if r.cold)
        durs = sorted(c["duration"] for c in self.cold_start_log)

        def pct(xs, q):
            return xs[min(len(xs) - 1, int(len(xs) * q))] if xs else 0.0

        return {
            "n": len(done),
            "ttft_attainment": ttft_ok / len(done),
            "tpot_attainment": tpot_ok / len(done),
            "ttft_mean": sum(ttfts) / len(ttfts),
            "ttft_p50": ttfts[len(ttfts) // 2],
            "ttft_p99": pct(ttfts, 0.99),
            "cold_starts": len(self.cold_start_log),
            # request-experienced cold-start latency: TTFT of requests that
            # arrived with no ready endpoint (prewarming shrinks these)
            "cold_requests": len(cold_ttfts),
            "cold_p50": pct(cold_ttfts, 0.50),
            "cold_p99": pct(cold_ttfts, 0.99),
            # provisioning durations (proactive placement shrinks these)
            "cold_start_p50": pct(durs, 0.50),
            "cold_start_p99": pct(durs, 0.99),
            "prewarms": sum(1 for c in self.cold_start_log
                            if c["reason"] == "prewarm"),
            "placements": len(self.placement_log),
        }
