"""starcoder2-7b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]

36 query heads: not divisible by TP=16 — GSPMD pads the head dim
(see DESIGN.md §5 and the roofline notes).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
))
