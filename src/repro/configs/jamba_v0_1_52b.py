"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]

Period of 8 layers: attention at slot 4, Mamba elsewhere; MoE on odd slots.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    expert_d_ff=14336,
    expert_sharding="expert",
    mixer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    mlp_pattern=("dense", "moe"),
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    fsdp=True,
))
