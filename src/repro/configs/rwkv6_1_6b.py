"""rwkv6-1.6b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]

head_size=64 -> 32 heads over d_model=2048.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=0,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    mixer_pattern=("rwkv",),
))
