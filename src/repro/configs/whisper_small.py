"""whisper-small [audio] — encoder-decoder backbone; conv frontend is a STUB
(``input_specs()`` provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,             # decoder layers
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    n_audio_frames=1500,
    pos_embed="learned",
    max_position=32_768,
))
