"""Architecture configs. ``load_all()`` imports every arch module so that
``get_config(name)`` can resolve by name."""

import importlib

_ARCH_MODULES = [
    "granite_3_8b",
    "internlm2_20b",
    "starcoder2_7b",
    "qwen1_5_32b",
    "qwen2_moe_a2_7b",
    "grok_1_314b",
    "llava_next_34b",
    "whisper_small",
    "jamba_v0_1_52b",
    "rwkv6_1_6b",
    "paper_models",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True


from repro.configs.base import (  # noqa: E402,F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    get_config,
    list_configs,
    smoke_variant,
)
