"""Models the paper itself evaluates (Fig. 8, Table 1): Llama2-7B/13B and
OPT-6.7B. Used by the cold-start benchmarks for byte-size fidelity
(Llama2-7B FP16 = 12.5 GB, Llama2-13B = 24.2 GB)."""

from repro.configs.base import ModelConfig, register

LLAMA2_7B = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
))

LLAMA2_13B = register(ModelConfig(
    name="llama2-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=13824,
    vocab=32000,
))

OPT_6_7B = register(ModelConfig(
    name="opt-6.7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=16384,
    vocab=50272,
))
