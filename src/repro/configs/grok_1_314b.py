"""grok-1-314b [moe] — 8 experts, top-2. [hf:xai-org/grok-1; unverified]

Only 8 (large) experts: shard the expert FFN dim over `model` (TP inside
expert) instead of EP, which would leave half the axis idle.
314B never fits one host -> Alg.1 is allowed a deeper pipeline (max_pp=8)
and consolidation targets the min-PP warm configuration (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    expert_d_ff=32768,
    expert_sharding="ffn",
    mlp_pattern=("moe",),
    max_pp=8,
    fsdp=True,
))
