"""Model / shape configuration registry.

Every assigned architecture is a ``ModelConfig``; every assigned input shape
is a ``ShapeConfig``.  ``(arch x shape)`` cells drive the smoke tests, the
multi-pod dry-run and the roofline table.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-pattern vocabulary (one period of the repeated block structure).
#   mixer:  'attn' | 'mamba' | 'rwkv'
#   mlp:    'dense' | 'moe'
# A uniform transformer has period length 1.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int                  # == n_heads for MHA; 0 for attn-free slots
    d_ff: int
    vocab: int

    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"          # 'rope' | 'learned'
    max_position: int = 1 << 19      # learned-pos table size / rope max
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0             # 0 -> d_ff
    capacity_factor: float = 1.25
    expert_sharding: str = "expert"  # 'expert' (EP over experts) | 'ffn' (TP inside expert)

    # --- hybrid / ssm ---
    mixer_pattern: Tuple[str, ...] = ("attn",)      # one period
    mlp_pattern: Tuple[str, ...] = ("dense",)       # one period (moe cadence)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    n_audio_frames: int = 1500       # stub frontend output length

    # --- vlm ---
    n_image_tokens: int = 0          # stub frontend output length

    dtype: str = "bfloat16"

    # --- serving-side metadata used by the cold-start controller ---
    # Max pipeline-parallel size Alg.1 may choose (paper default 4).
    max_pp: int = 4

    # FSDP: additionally shard weights' d_model dim over 'data' (needed for
    # archs whose TP=16 param slice exceeds one chip's HBM).
    fsdp: bool = False

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and self.expert_d_ff == 0:
            object.__setattr__(self, "expert_d_ff", self.d_ff)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to a multiple of 256 so the vocab dim
        shards evenly on TP=16/32 (pad logits are masked in the head)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_free(self) -> bool:
        return all(m != "attn" for m in self.mixer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch supports 500k-token decode (SSM / hybrid)."""
        return any(m in ("mamba", "rwkv") for m in self.mixer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.mixer_pattern) == 0, self.name
        return self.n_layers // len(self.mixer_pattern)

    @property
    def layer_plan(self) -> Tuple[Tuple[str, str], ...]:
        """Full per-layer (mixer, mlp) plan, length n_layers."""
        plan = []
        for _ in range(self.n_periods):
            for i, mix in enumerate(self.mixer_pattern):
                plan.append((mix, self.mlp_pattern[i % len(self.mlp_pattern)]))
        return tuple(plan)

    # ------------------------------------------------------------------
    # Parameter counting (used for fetch-time modelling and rooflines).
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab * d                       # tok embed
        if not self.tie_embeddings:
            total += self.vocab * d                  # lm head
        total += d                                   # final norm

        def attn_params() -> int:
            p = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            if self.qkv_bias:
                p += n_q * hd + 2 * n_kv * hd
            return p + d                             # + pre-norm

        def dense_mlp() -> int:
            return 3 * d * ff + d                    # gate/up/down + pre-norm

        def moe_mlp() -> int:
            eff = self.expert_d_ff
            p = self.n_experts * 3 * d * eff + d * self.n_experts  # experts + router
            if self.n_shared_experts:
                p += 3 * d * (eff * self.n_shared_experts)
            return p + d

        def mamba_params() -> int:
            d_in = self.mamba_expand * d
            n = self.mamba_d_state
            p = d * 2 * d_in                          # in_proj
            p += d_in * self.mamba_d_conv + d_in      # conv
            p += d_in * (n * 2 + d_in // 16) + (d_in // 16) * d_in  # x_proj + dt_proj
            p += d_in * n + d_in                      # A_log, D
            p += d_in * d                             # out_proj
            return p + d

        def rwkv_params() -> int:
            # time-mix r/k/v/g/o + data-dependent decay lora + channel-mix
            p = 5 * d * d + 2 * (d * 64 + 64 * d) + 6 * d
            return p + d

        mixer_cost = {"attn": attn_params, "mamba": mamba_params, "rwkv": rwkv_params}
        mlp_cost = {"dense": dense_mlp, "moe": moe_mlp, "none": lambda: 0}
        for mix, mlp in self.layer_plan:
            total += mixer_cost[mix]()
            total += mlp_cost[mlp]()
        if self.is_encdec:
            # encoder self-attn + dense mlp + cross-attn params in decoder
            total += self.encoder_layers * (attn_params() + dense_mlp())
            total += self.n_layers * attn_params()   # cross attention
            total += self.n_audio_frames * d         # encoder pos embed (stub side)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d, eff = self.d_model, self.expert_d_ff
        inactive = 0
        for _, mlp in self.layer_plan:
            if mlp == "moe":
                inactive += (self.n_experts - self.top_k) * 3 * d * eff
        return self.param_count() - inactive

    def size_bytes(self, bytes_per_param: int = 2) -> int:
        return self.param_count() * bytes_per_param


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ModelConfig):
    """Assigned-shape cells for one arch (skips recorded in DESIGN.md §5)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs  # noqa: F401
        configs.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    from repro import configs
    configs.load_all()
    return dict(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    period = len(cfg.mixer_pattern)
    n_layers = max(period, 2 if period == 1 else period)
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab=512,
        dtype="float32",
    )
    if cfg.is_moe:
        updates.update(n_experts=4, top_k=min(cfg.top_k, 2), expert_d_ff=64,
                       n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.is_encdec:
        updates.update(encoder_layers=2, n_audio_frames=8)
    if cfg.n_image_tokens:
        updates.update(n_image_tokens=4)
    if cfg.attn_free:
        updates.update(n_heads=4, n_kv_heads=0, head_dim=16)
    return dataclasses.replace(cfg, **updates)
