"""llava-next-34b [vlm] — transformer backbone only; anyres vision tower is a
STUB: ``input_specs()`` provides precomputed patch embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_image_tokens=576,
    fsdp=True,
))
