"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 experts don't divide TP=16, so the baseline shards the expert FFN dim
(1408/16=88); the §Perf hillclimb evaluates padding 60->64 experts for EP.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    expert_d_ff=1408,
    expert_sharding="ffn",
    mlp_pattern=("moe",),
))
