"""Deterministic discrete-event simulator core."""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "cancelled")

    def __init__(self, time: float, seq: int, fn: Callable[[], None]):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False

    def __lt__(self, other):
        return (self.time, self.seq) < (other.time, other.seq)


class EventSim:
    def __init__(self):
        self._heap = []
        self._counter = itertools.count()
        self.now = 0.0

    def at(self, time: float, fn: Callable[[], None]) -> Event:
        assert time >= self.now - 1e-9, (time, self.now)
        ev = Event(max(time, self.now), next(self._counter), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, delay: float, fn: Callable[[], None]) -> Event:
        return self.at(self.now + max(delay, 0.0), fn)

    def cancel(self, ev: Optional[Event]):
        if ev is not None:
            ev.cancelled = True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000):
        n = 0
        while self._heap and n < max_events:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if until is not None and ev.time > until:
                self.now = until
                return
            self.now = ev.time
            ev.fn()
            n += 1
        if until is not None:
            self.now = until
