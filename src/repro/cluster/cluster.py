"""Simulated GPU/TPU cluster: per-server fair-share NIC (weighted fluid
model), per-device HBM accounting, host-memory model cache, and a remote
model registry with unbounded egress (fetch is bottlenecked by the
receiving server's NIC, as in the paper's testbeds)."""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.cluster.sim import EventSim
from repro.core.types import GB, ServerSpec


@dataclass
class Flow:
    """One remote->host fetch on a server NIC."""
    flow_id: int
    server_id: str
    remaining: float                # bytes
    weight: float                   # priority weight for fair share
    on_done: Callable[[], None]
    rate: float = 0.0
    done: bool = False
    _completion_ev: object = None


@dataclass
class Device:
    device_id: str
    hbm_total: int
    hbm_free: int


class Server:
    def __init__(self, spec: ServerSpec, host_mem_bytes: int):
        self.spec = spec
        self.devices = [
            Device(f"{spec.server_id}/dev{i}", spec.hbm_bytes, spec.hbm_bytes)
            for i in range(spec.n_devices)
        ]
        self.host_mem_total = host_mem_bytes
        self.host_mem_free = host_mem_bytes
        self.flows: Dict[int, Flow] = {}
        self.cached_models: Dict[str, int] = {}     # model -> bytes (LRU)
        self._lru: List[str] = []

    # ------------------------------------------------------------- memory
    def fit_device(self, need: int) -> Optional[Device]:
        for d in self.devices:
            if d.hbm_free >= need:
                return d
        return None

    def max_free_hbm(self) -> int:
        return max((d.hbm_free for d in self.devices), default=0)

    def alloc(self, device: Device, amount: int):
        assert device.hbm_free >= amount, (device.device_id, amount)
        device.hbm_free -= amount

    def free(self, device: Device, amount: int):
        device.hbm_free = min(device.hbm_free + amount, device.hbm_total)

    # --------------------------------------------------------- host cache
    def cache_touch(self, model: str):
        if model in self._lru:
            self._lru.remove(model)
            self._lru.append(model)

    def cache_put(self, model: str, size: int) -> bool:
        if model in self.cached_models:
            self.cache_touch(model)
            return True
        while self.host_mem_free < size and self._lru:
            evict = self._lru.pop(0)
            self.host_mem_free += self.cached_models.pop(evict)
        if self.host_mem_free < size:
            return False
        self.host_mem_free -= size
        self.cached_models[model] = size
        self._lru.append(model)
        return True

    def cache_has(self, model: str) -> bool:
        return model in self.cached_models


class Cluster:
    """Servers + the weighted-fair-share NIC fluid model.

    Every flow on a server receives bandwidth B * w_f / sum(w); on any flow
    set change we settle elapsed progress and recompute completion events.
    """

    def __init__(self, sim: EventSim, servers: List[ServerSpec],
                 host_mem_bytes: int = 188 * GB):
        self.sim = sim
        self.servers: Dict[str, Server] = {
            s.server_id: Server(s, host_mem_bytes) for s in servers}
        self._flow_counter = 0
        self._last_settle: Dict[str, float] = {s.server_id: 0.0
                                               for s in servers}

    # ------------------------------------------------------------ network
    def _settle(self, server: Server):
        now = self.sim.now
        last = self._last_settle[server.spec.server_id]
        dt = now - last
        if dt > 0:
            for f in server.flows.values():
                f.remaining = max(0.0, f.remaining - f.rate * dt)
        self._last_settle[server.spec.server_id] = now

    def _reschedule(self, server: Server):
        self._settle(server)
        total_w = sum(f.weight for f in server.flows.values())
        bw = server.spec.nic_bytes_per_s
        for f in server.flows.values():
            self.sim.cancel(f._completion_ev)
            f.rate = bw * (f.weight / total_w) if total_w else 0.0
            if f.rate <= 0:
                continue
            eta = f.remaining / f.rate
            fid = f.flow_id
            f._completion_ev = self.sim.after(
                eta, lambda fid=fid, sid=server.spec.server_id:
                self._finish_flow(sid, fid))

    def _finish_flow(self, server_id: str, flow_id: int):
        server = self.servers[server_id]
        f = server.flows.get(flow_id)
        if f is None or f.done:
            return
        self._settle(server)
        # done-threshold is in *bytes*: float time resolution (~fs) times
        # GB/s rates leaves micro-byte residuals that must count as done
        if f.remaining > 1.0:       # stale event after resettle
            self._reschedule(server)
            return
        f.done = True
        del server.flows[flow_id]
        self._reschedule(server)
        f.on_done()

    def start_fetch(self, server_id: str, nbytes: float,
                    on_done: Callable[[], None], weight: float = 1.0) -> Flow:
        server = self.servers[server_id]
        self._flow_counter += 1
        f = Flow(self._flow_counter, server_id, float(nbytes), weight, on_done)
        if nbytes <= 0:
            self.sim.after(0.0, on_done)
            f.done = True
            return f
        server.flows[f.flow_id] = f
        self._reschedule(server)
        return f

    def cancel_fetch(self, flow: Flow):
        server = self.servers[flow.server_id]
        if flow.flow_id in server.flows:
            self._settle(server)
            self.sim.cancel(flow._completion_ev)
            del server.flows[flow.flow_id]
            flow.done = True
            self._reschedule(server)

    def flow_progress(self, flow: Flow) -> float:
        """Bytes still pending (after settling)."""
        if flow.done:
            return 0.0
        self._settle(self.servers[flow.server_id])
        return flow.remaining

    # ------------------------------------------------------------ helpers
    def specs(self) -> Dict[str, ServerSpec]:
        return {sid: s.spec for sid, s in self.servers.items()}

    def free_hbm(self) -> Dict[str, int]:
        return {sid: s.max_free_hbm() for sid, s in self.servers.items()}
