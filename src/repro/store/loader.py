"""Streamed, overlap-scheduled stage loading (§5 made real).

``StreamedStageLoader`` materializes a pipeline stage's parameters
tensor-by-tensor in manifest order, straight from a ``ModelStore`` tier's
byte ranges. Container / library / accelerator-context spans are stubbed
from the ``TimingProfile`` (this process *is* already a warm runtime);
fetch and load spans are **measured** — driven by the actual per-tensor
byte counts through the contention-aware ``FetchSchedule`` (fetch) and a
configured load bandwidth (PCIe leg). The result is a
``WorkerTimeline``-compatible record honoring ``OverlapFlags``:

  * no ``prefetch``  — the fetch flow is admitted only after the full
    runtime init (container + lib + cuda), whichever order the flags
    put those in;
  * no ``stream``    — tensors are loaded only once the *entire* stage
    fetch has finished, instead of as each tensor arrives;
  * no ``overlap_load`` — runtime init is cc -> lib -> cuda and loading
    waits for all of it; with it, cc -> cuda and lib runs concurrent
    with loading (ready still waits for lib).

Under matched bandwidths the measured spans converge to
``core.coldstart.worker_timeline``'s analytic ones as tensor count grows
(the stream pipeline's residual is one tensor's transfer) — asserted
within 5% by tests and the fig8/fig9 ``--real-loader`` cross-checks.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.coldstart import OverlapFlags, WorkerTimeline
from repro.core.types import TimingProfile
from repro.store.manifest import unflatten_paths
from repro.store.store import FetchSchedule, ModelStore


@dataclass
class TensorSpan:
    """Per-tensor stream record: when its bytes arrived and when its
    load (host -> device) leg ran. With ``stream`` the accounted DMA
    chases the byte-arrival profile (a tensor's copy overlaps its own
    fetch tail, like a real pinned-buffer DMA); the jnp materialization
    itself stays tensor-granular."""
    key: str
    nbytes: int
    fetch_start: float
    fetch_end: float
    load_start: float
    load_end: float


@dataclass
class StageLoadRecord:
    """Measured cold-start record for one stage worker —
    ``timeline.spans`` uses the same stage names/conventions as the
    analytic ``worker_timeline`` so the two are directly comparable."""
    stage: int
    n_stages: int
    server_id: str
    tier: str
    fetched_bytes: int
    timeline: WorkerTimeline
    tensors: List[TensorSpan] = field(default_factory=list)

    @property
    def ready(self) -> float:
        return self.timeline.ready

    def to_json(self) -> dict:
        return {
            "stage": self.stage, "n_stages": self.n_stages,
            "server": self.server_id, "tier": self.tier,
            "fetched_bytes": self.fetched_bytes,
            "ready": self.timeline.ready,
            "spans": {k: list(v) for k, v in self.timeline.spans.items()},
            "n_tensors": len(self.tensors),
        }


@dataclass
class ColdStartReport:
    """What a whole cold start measured: one record per stage worker."""
    model: str
    s: int
    flags: OverlapFlags
    stages: List[StageLoadRecord]

    @property
    def ready(self) -> float:
        return max(r.timeline.ready for r in self.stages)

    @property
    def total_bytes(self) -> int:
        return sum(r.fetched_bytes for r in self.stages)

    def to_json(self) -> dict:
        return {
            "model": self.model, "s": self.s,
            "flags": {"prefetch": self.flags.prefetch,
                      "stream": self.flags.stream,
                      "overlap_load": self.flags.overlap_load},
            "ready": self.ready, "total_bytes": self.total_bytes,
            "stages": [r.to_json() for r in self.stages],
        }


class StreamedStageLoader:
    """Loads stage parameter slices out of a ``ModelStore`` while
    accounting a measured cold-start timeline on the fetch schedule's
    simulated clock."""

    def __init__(self, store: ModelStore, schedule: FetchSchedule,
                 timings: Optional[TimingProfile] = None,
                 flags: OverlapFlags = OverlapFlags.all(),
                 load_bytes_per_s: float = 12e9,
                 tier: Optional[str] = None):
        self.store = store
        self.schedule = schedule
        self.timings = timings or TimingProfile()
        self.flags = flags
        self.load_bw = float(load_bytes_per_s)
        self.tier_name = store.tier(tier).name

    # ----------------------------------------------------------- internals
    def _runtime_spans(self, start: float) -> Dict[str, Tuple[float, float]]:
        """Container / lib / cuda spans stubbed from the TimingProfile,
        in the order the flags dictate (same rules as worker_timeline)."""
        t = self.timings
        spans = {"container": (start, start + t.t_cc)}
        cc_end = start + t.t_cc
        if self.flags.overlap_load:
            spans["cuda"] = (cc_end, cc_end + t.t_cu)
            spans["lib"] = (cc_end + t.t_cu, cc_end + t.t_cu + t.t_l)
        else:
            spans["lib"] = (cc_end, cc_end + t.t_l)
            spans["cuda"] = (cc_end + t.t_l, cc_end + t.t_l + t.t_cu)
        return spans

    # -------------------------------------------------------------- public
    def admit_stage(self, n_stages: int, stage: int, *,
                    server_id: str = "local", worker_id: str = "w0",
                    now: float = 0.0, deadline: float = math.inf):
        """Phase 1: start the stage's fetch flow (prefetch semantics
        decide when relative to runtime init). Admit every stage of a
        group — and any concurrently cold-starting group — before
        materializing, so same-server flows contend (Alg. 2)."""
        spans = self._runtime_spans(now)
        runtime_end = max(spans["lib"][1], spans["cuda"][1])
        fetch_start = now if self.flags.prefetch else runtime_end
        nbytes = self.store.stage_bytes(n_stages, stage)
        cap = self.store.tier(self.tier_name).bandwidth
        flow = self.schedule.admit(server_id, worker_id, nbytes,
                                   now=fetch_start, cap=cap,
                                   deadline=deadline)
        return _PendingStage(self, n_stages, stage, server_id, now, spans,
                             flow)

    def load_stage(self, n_stages: int, stage: int, *,
                   server_id: str = "local", worker_id: str = "w0",
                   now: float = 0.0, deadline: float = math.inf):
        """Admit + materialize one stage (single-worker convenience).
        Returns ``(stage_params, StageLoadRecord)``."""
        return self.admit_stage(n_stages, stage, server_id=server_id,
                                worker_id=worker_id, now=now,
                                deadline=deadline).materialize()

    def load_group(self, n_stages: int, *, servers=None, now: float = 0.0,
                   worker_ids=None, deadline: float = math.inf,
                   model_name: Optional[str] = None):
        """Cold-start a whole pipeline group: admit all stage flows first
        (so stages placed on the same server contend for its NIC), then
        materialize each. Returns ``(stage_params_list, ColdStartReport)``.
        """
        servers = list(servers or ["local"] * n_stages)
        worker_ids = list(worker_ids
                          or [f"stage{i}" for i in range(n_stages)])
        pending = [self.admit_stage(n_stages, i, server_id=servers[i],
                                    worker_id=worker_ids[i], now=now,
                                    deadline=deadline)
                   for i in range(n_stages)]
        params, records = [], []
        for p in pending:
            sp, rec = p.materialize()
            params.append(sp)
            records.append(rec)
        report = ColdStartReport(model_name or self.store.manifest.model,
                                 n_stages, self.flags, records)
        return params, report


class _PendingStage:
    """A stage whose fetch flow is admitted but not yet materialized."""

    def __init__(self, loader: StreamedStageLoader, n_stages: int,
                 stage: int, server_id: str, start: float, spans, flow):
        self.loader = loader
        self.n_stages = n_stages
        self.stage = stage
        self.server_id = server_id
        self.start = start
        self.spans = spans
        self.flow = flow

    def materialize(self):
        """Phase 2: resolve the fetch on the simulated clock and stream
        the tensors — each chunk range is *actually read* from the tier
        and built into the stage's param subtree; its fetch/load instants
        come from the flow's measured byte-arrival profile."""
        ld = self.loader
        flags, spans = ld.flags, dict(self.spans)
        flow = ld.schedule.resolve(self.flow)
        plan = ld.store.stage_plan(self.n_stages, self.stage)
        cuda_end = spans["cuda"][1]
        lib_end = spans["lib"][1]

        fetch_end = flow.end
        load_begin = max(cuda_end, flow.start)
        cursor = load_begin if flags.stream \
            else max(fetch_end, load_begin)
        leaves = {}
        tensors: List[TensorSpan] = []
        cum = 0
        for sc in plan:
            arrive_begin = flow.time_at_bytes(cum)
            cum += sc.length
            arrive_end = flow.time_at_bytes(cum)
            data = ld.store.read_range(sc.chunk, sc.offset, sc.length,
                                       tier=ld.tier_name)
            leaves[sc.chunk.path] = jnp.asarray(data.reshape(sc.shape))
            if flags.stream:
                # DMA chases the arrival stream: it can start on the
                # tensor's first byte and finishes no earlier than its
                # last byte lands (and no faster than the PCIe leg)
                t0 = max(cursor, arrive_begin)
                t1 = max(arrive_end, t0 + sc.length / ld.load_bw)
            else:
                t0 = cursor
                t1 = t0 + sc.length / ld.load_bw
            tensors.append(TensorSpan(sc.chunk.key, sc.length,
                                      arrive_begin, arrive_end, t0, t1))
            cursor = t1
        load_end = max(cursor, fetch_end) if not tensors else cursor
        spans["fetch"] = (flow.start, fetch_end)
        spans["load"] = (load_begin, load_end)
        ready = max(load_end, lib_end)
        assert all(s0 <= s1 + 1e-12 for s0, s1 in spans.values())
        timeline = WorkerTimeline(ready=ready, spans=spans)
        record = StageLoadRecord(self.stage, self.n_stages, self.server_id,
                                 ld.tier_name, int(flow.size), timeline,
                                 tensors)
        return unflatten_paths(leaves), record
