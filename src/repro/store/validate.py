"""Cross-check the measured (executed) cold-start timeline against the
analytic ``worker_timeline`` under matched bandwidths.

This is the bridge the repro was missing: ``core.coldstart`` predicts the
Fig. 9 spans from aggregate (bytes, bandwidth) pairs; the
``StreamedStageLoader`` *executes* the same schedule tensor-by-tensor.
Under equal bandwidths the two must agree — exactly for the
container/lib/cuda stubs and the fetch span, and within a small relative
tolerance (one tensor's worth of pipeline residual) for the streamed
load span and readiness.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.coldstart import OverlapFlags, WorkerTimeline, \
    worker_timeline
from repro.core.types import TimingProfile
from repro.store.loader import StageLoadRecord, StreamedStageLoader
from repro.store.store import FetchSchedule, ModelStore

DEFAULT_TOL = 0.05                   # the 5% acceptance bound


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-9)


@dataclass
class StageCrossCheck:
    stage: int
    measured: StageLoadRecord
    analytic: WorkerTimeline

    @property
    def ready_err(self) -> float:
        return _rel_err(self.measured.timeline.ready, self.analytic.ready)

    def span_errs(self) -> dict:
        out = {}
        for name, (a0, a1) in self.analytic.spans.items():
            m0, m1 = self.measured.timeline.spans[name]
            scale = max(a1 - a0, a1, 1e-9)
            out[name] = max(abs(m0 - a0), abs(m1 - a1)) / scale
        return out

    @property
    def max_err(self) -> float:
        return max(self.ready_err, *self.span_errs().values())

    def to_json(self) -> dict:
        return {
            "stage": self.stage,
            "measured_ready": self.measured.timeline.ready,
            "analytic_ready": self.analytic.ready,
            "ready_err": self.ready_err,
            "span_errs": self.span_errs(),
            "measured_spans": {k: list(v) for k, v
                               in self.measured.timeline.spans.items()},
            "analytic_spans": {k: list(v) for k, v
                               in self.analytic.spans.items()},
        }


def crosscheck_stages(store: ModelStore, s: int, *,
                      timings: Optional[TimingProfile] = None,
                      flags: OverlapFlags = OverlapFlags.all(),
                      nic_bytes_per_s: float,
                      load_bytes_per_s: float,
                      tier: Optional[str] = None,
                      start: float = 0.0) -> List[StageCrossCheck]:
    """Run the real loader for every stage of an s-way cold start — one
    uncontended server per stage — and pair each measured record with the
    analytic ``worker_timeline`` fed the *same* byte counts and
    bandwidths. The analytic fetch bandwidth is ``min(nic, tier)``, which
    is what a single flow on an idle NIC gets."""
    timings = timings or TimingProfile()
    checks: List[StageCrossCheck] = []
    tier_bw = store.tier(tier).bandwidth
    eff_bw = min(nic_bytes_per_s, tier_bw)
    for stage in range(s):
        sched = FetchSchedule.single(nic_bytes_per_s,
                                     server_id=f"xsrv{stage}")
        loader = StreamedStageLoader(store, sched, timings, flags,
                                     load_bytes_per_s=load_bytes_per_s,
                                     tier=tier)
        _, rec = loader.load_stage(s, stage, server_id=f"xsrv{stage}",
                                   worker_id=f"xchk{stage}", now=start)
        nbytes = store.stage_bytes(s, stage)
        ana = worker_timeline(timings, nbytes / eff_bw,
                              nbytes / load_bytes_per_s, flags, start)
        checks.append(StageCrossCheck(stage, rec, ana))
    return checks


def assert_within(checks: List[StageCrossCheck],
                  tol: float = DEFAULT_TOL) -> float:
    worst = max(c.max_err for c in checks)
    assert worst <= tol, (
        f"measured cold-start spans drifted {worst:.1%} from the analytic "
        f"worker_timeline (> {tol:.0%}): "
        f"{[(c.stage, c.span_errs()) for c in checks]}")
    return worst
