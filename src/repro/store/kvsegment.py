"""Content-addressed KV *segment* tier — the bottom of the multi-tier
KV cache (HBM page pool → host tier → segment store).

The host tier (repro/router/kvtier.py ``KVBlockStore``) holds spilled
pages as live numpy arrays under a bounded block budget; when it
overflows, the LRU entry is *demoted* here. This tier is the KV
analogue of the model ``ModelStore``: payloads are **serialized** to raw
bytes (the same ``tobytes`` round trip the model chunk store uses, so a
segment surviving a demote/restore cycle is bit-exact by construction)
and reads are charged at the tier's configured bandwidth — typically the
remote/registry class, an order of magnitude under the host tier's PCIe
class — on the same contention-fair ``FetchSchedule`` as every other
transfer in the system.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kvcache import KVInvariantError
from repro.store.store import REMOTE_BW

__all__ = ["KVSegmentStore"]


class KVSegmentStore:
    """Serialized KV segments keyed by block-chain hash.

    A *segment* is one spilled KV block's payload: an ordered list of
    ``(cache_slot_name, k_pages, v_pages)`` triples covering every
    attention period of the model (pipeline-shape independent — see
    ``KVBlockStore``). ``put`` serializes the arrays; ``get``
    reconstructs them bit-exactly. Transfer-time accounting belongs to
    the caller (``KVBlockStore`` charges ``bytes_of`` at
    ``bandwidth``)."""

    def __init__(self, bandwidth: float = REMOTE_BW):
        self.bandwidth = float(bandwidth)
        # hash -> list of (name, (k bytes, v bytes), dtype str, shape,
        # aux) where aux is None or serialized quant leaves
        self._segs: Dict[bytes, List[Tuple]] = {}
        self._nbytes: Dict[bytes, int] = {}

    # --------------------------------------------------------------- api
    def has(self, h: bytes) -> bool:
        return h in self._segs

    def __len__(self) -> int:
        return len(self._segs)

    @property
    def total_bytes(self) -> int:
        return sum(self._nbytes.values())

    def bytes_of(self, h: bytes) -> int:
        return self._nbytes[h]

    def put(self, h: bytes, payload: List[Tuple]):
        seg = []
        nbytes = 0
        for entry in payload:
            name, k, v = entry[0], entry[1], entry[2]
            k = np.ascontiguousarray(k)
            v = np.ascontiguousarray(v)
            if k.shape != v.shape or k.dtype != v.dtype:
                raise KVInvariantError(
                    f"segment K/V mismatch: {k.shape}/{k.dtype} vs "
                    f"{v.shape}/{v.dtype}")
            aux = None
            if len(entry) > 3:
                # quantized pools: serialize the scale/zero leaves too —
                # they are part of the block's content and its byte count
                aux = []
                for leaf, a in entry[3].items():
                    a = np.ascontiguousarray(a)
                    aux.append((leaf, a.tobytes(), str(a.dtype), a.shape))
                    nbytes += a.nbytes
            seg.append((name, (k.tobytes(), v.tobytes()),
                        str(k.dtype), k.shape, aux))
            nbytes += k.nbytes + v.nbytes
        self._segs[h] = seg
        self._nbytes[h] = nbytes

    def get(self, h: bytes) -> List[Tuple]:
        out = []
        for name, (kb, vb), dtype, shape, aux in self._segs[h]:
            k = np.frombuffer(kb, dtype=dtype).reshape(shape)
            v = np.frombuffer(vb, dtype=dtype).reshape(shape)
            if aux is None:
                out.append((name, k, v))
            else:
                d = {leaf: np.frombuffer(ab, dtype=adt).reshape(ashp)
                     for leaf, ab, adt, ashp in aux}
                out.append((name, k, v, d))
        return out

    def pop(self, h: bytes) -> List[Tuple]:
        out = self.get(h)
        del self._segs[h]
        del self._nbytes[h]
        return out

    def discard(self, h: Optional[bytes]):
        self._segs.pop(h, None)
        self._nbytes.pop(h, None)
