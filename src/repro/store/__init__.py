"""Cold-start data plane: chunked model store + streamed stage loading.

``manifest``  — per-tensor chunk files + stage byte ranges per degree;
``store``     — tiered byte sources (local/peer/remote) and the
                contention-aware simulated-clock ``FetchSchedule``;
``loader``    — ``StreamedStageLoader``: materializes stage params
                tensor-by-tensor with a measured ``WorkerTimeline``;
``validate``  — measured-vs-analytic cross-checks (fig8/fig9
                ``--real-loader``, CI smoke, tests);
``kvsegment`` — serialized KV *segment* tier: the bottom of the
                multi-tier KV cache (HBM → host → store), backing the
                router's ``KVBlockStore`` overflow.
"""

from repro.store.kvsegment import KVSegmentStore
from repro.store.loader import (ColdStartReport, StageLoadRecord,
                                StreamedStageLoader, TensorSpan)
from repro.store.manifest import (ChunkRecord, Manifest, StageChunk,
                                  build_manifest, load_manifest, save_model)
from repro.store.store import (AliasTier, DiskTier, FetchFlow, FetchSchedule,
                               MemoryTier, ModelStore, StoreTier)
from repro.store.validate import (StageCrossCheck, assert_within,
                                  crosscheck_stages)

__all__ = [
    "ChunkRecord", "Manifest", "StageChunk", "build_manifest",
    "load_manifest", "save_model",
    "AliasTier", "DiskTier", "FetchFlow", "FetchSchedule", "MemoryTier",
    "ModelStore", "StoreTier",
    "ColdStartReport", "StageLoadRecord", "StreamedStageLoader",
    "TensorSpan", "KVSegmentStore",
    "StageCrossCheck", "assert_within", "crosscheck_stages",
]
