"""Chunked model manifests: the on-disk (or in-memory) layout behind the
cold-start data plane.

``save_model`` extends the checkpoint manager's manifest idea to serving:
every pytree leaf becomes one raw-bytes chunk file, and the manifest
additionally records, for every pipeline degree the model supports, which
stage owns which byte range of which chunk (via ``Model.stage_ranges``).
Period-stacked ``blocks/...`` leaves are row-major with the period axis
leading, so a stage's slice of a block chunk is a *contiguous byte range*
``[p0 * row_bytes, p1 * row_bytes)`` — a worker fetches exactly its
stage's bytes, never a slice of a live dict.

Roles mirror ``Model.slice_stage_params``:
  * ``block`` — period-stacked, split across stages by byte range;
  * ``first`` — embed / encoder leaves owned by stage 0;
  * ``last``  — final_norm / lm_head leaves owned by stage s-1.
"""

from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import encode_key, fsync_dir

MANIFEST_NAME = "manifest.json"
CHUNK_DIR = "chunks"
_LAST_ROOTS = ("final_norm", "lm_head")


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype string, including ml_dtypes extras (bfloat16)."""
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        return np.dtype(getattr(jnp, name))


def flatten_with_paths(tree) -> Dict[Tuple[str, ...], np.ndarray]:
    """Leaves keyed by their path components (no separator ambiguity)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)
        out[key] = leaf
    return out


def unflatten_paths(leaves: Dict[Tuple[str, ...], object]) -> dict:
    """Rebuild the nested-dict tree from path-component keys."""
    tree: dict = {}
    for path, leaf in leaves.items():
        node = tree
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = leaf
    return tree


@dataclass(frozen=True)
class ChunkRecord:
    """One tensor's chunk: raw little-endian bytes of the C-contiguous
    array (``arr.tobytes()``), addressable by byte range."""
    index: int                       # manifest (stream) order
    path: Tuple[str, ...]            # tree path components
    file: str                        # chunk file name under chunks/
    dtype: str
    shape: Tuple[int, ...]
    nbytes: int
    role: str                        # block | first | last

    @property
    def key(self) -> str:
        return "/".join(self.path)

    @property
    def row_bytes(self) -> int:
        """Bytes per leading-axis row (the period axis for block chunks)."""
        assert self.role == "block" and self.shape
        return self.nbytes // self.shape[0]


@dataclass(frozen=True)
class StageChunk:
    """One entry of a stage's fetch plan: a byte range of a chunk, plus
    the shape the range materializes to."""
    chunk: ChunkRecord
    offset: int
    length: int
    shape: Tuple[int, ...]


@dataclass
class Manifest:
    model: str
    dtype: str
    n_periods: int
    total_bytes: int
    chunks: List[ChunkRecord] = field(default_factory=list)
    # pipeline degree -> per-stage (p0, p1) period ranges
    stage_ranges: Dict[int, List[Tuple[int, int]]] = field(
        default_factory=dict)

    # ------------------------------------------------------------ queries
    @property
    def degrees(self) -> List[int]:
        return sorted(self.stage_ranges)

    def stage_plan(self, s: int, stage: int) -> List[StageChunk]:
        """The ordered byte ranges a stage-``stage`` worker of an s-way
        pipeline must fetch (manifest order == stream order)."""
        if s not in self.stage_ranges:
            raise KeyError(f"pipeline degree {s} not in manifest "
                           f"(has {self.degrees})")
        p0, p1 = self.stage_ranges[s][stage]
        plan: List[StageChunk] = []
        for c in self.chunks:
            if c.role == "block":
                if p1 <= p0:
                    continue
                rb = c.row_bytes
                plan.append(StageChunk(c, p0 * rb, (p1 - p0) * rb,
                                       (p1 - p0,) + tuple(c.shape[1:])))
            elif c.role == "first" and stage == 0:
                plan.append(StageChunk(c, 0, c.nbytes, tuple(c.shape)))
            elif c.role == "last" and stage == s - 1:
                plan.append(StageChunk(c, 0, c.nbytes, tuple(c.shape)))
        return plan

    def stage_bytes(self, s: int, stage: int) -> int:
        return sum(sc.length for sc in self.stage_plan(s, stage))

    # -------------------------------------------------------------- (de)ser
    def to_json(self) -> dict:
        return {
            "model": self.model, "dtype": self.dtype,
            "n_periods": self.n_periods, "total_bytes": self.total_bytes,
            "stage_ranges": {str(s): [list(r) for r in ranges]
                             for s, ranges in self.stage_ranges.items()},
            "chunks": [{
                "index": c.index, "path": list(c.path), "file": c.file,
                "dtype": c.dtype, "shape": list(c.shape),
                "nbytes": c.nbytes, "role": c.role,
            } for c in self.chunks],
        }

    @staticmethod
    def from_json(d: dict) -> "Manifest":
        return Manifest(
            model=d["model"], dtype=d["dtype"],
            n_periods=int(d["n_periods"]),
            total_bytes=int(d["total_bytes"]),
            chunks=[ChunkRecord(index=int(c["index"]),
                                path=tuple(c["path"]), file=c["file"],
                                dtype=c["dtype"], shape=tuple(c["shape"]),
                                nbytes=int(c["nbytes"]), role=c["role"])
                    for c in d["chunks"]],
            stage_ranges={int(s): [tuple(r) for r in ranges]
                          for s, ranges in d["stage_ranges"].items()})


def _role_of(path: Tuple[str, ...]) -> str:
    if path[0] == "blocks":
        return "block"
    if path[0] in _LAST_ROOTS:
        return "last"
    return "first"                   # embed / encoder / enc_final_norm / ...


def build_manifest(model, params,
                   degrees=None) -> Tuple[Manifest,
                                          Dict[str, np.ndarray]]:
    """Chunk a live param tree: returns the manifest plus ``file -> array``
    (C-contiguous host arrays whose ``tobytes()`` are the chunk bytes)."""
    cfg = model.cfg
    if degrees is None:
        degrees = range(1, cfg.n_periods + 1)
    leaves = flatten_with_paths(params)
    chunks: List[ChunkRecord] = []
    arrays: Dict[str, np.ndarray] = {}
    total = 0
    for i, (path, leaf) in enumerate(leaves.items()):
        arr = np.ascontiguousarray(np.asarray(leaf))
        role = _role_of(path)
        if role == "block":
            assert arr.shape[0] == cfg.n_periods, \
                f"block leaf {'/'.join(path)} not period-stacked"
        fname = f"{i:04d}__{encode_key('/'.join(path))}.bin"
        chunks.append(ChunkRecord(index=i, path=path, file=fname,
                                  dtype=str(arr.dtype),
                                  shape=tuple(arr.shape),
                                  nbytes=arr.nbytes, role=role))
        arrays[fname] = arr
        total += arr.nbytes
    ranges = {int(s): [tuple(r) for r in model.stage_ranges(int(s))]
              for s in degrees}
    return Manifest(model=cfg.name, dtype=cfg.dtype,
                    n_periods=cfg.n_periods, total_bytes=total,
                    chunks=chunks, stage_ranges=ranges), arrays


def save_model(directory: str, model, params, degrees=None) -> Manifest:
    """Write the chunked store: ``chunks/*.bin`` raw tensors plus an
    atomically-committed ``manifest.json`` (same commit discipline as the
    checkpoint manager: temp file + fsync + rename + parent-dir fsync —
    a store without a manifest is not a store)."""
    manifest, arrays = build_manifest(model, params, degrees)
    cdir = os.path.join(directory, CHUNK_DIR)
    os.makedirs(cdir, exist_ok=True)
    for fname, arr in arrays.items():
        with open(os.path.join(cdir, fname), "wb") as f:
            f.write(arr.tobytes())
            f.flush()
            os.fsync(f.fileno())
    fsync_dir(cdir)
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".manifest-")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(manifest.to_json(), f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(directory, MANIFEST_NAME))
        fsync_dir(directory)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return manifest


def load_manifest(directory: str) -> Manifest:
    with open(os.path.join(directory, MANIFEST_NAME)) as f:
        return Manifest.from_json(json.load(f))
