"""Tiered model store + the simulated-clock fetch schedule.

``ModelStore`` answers "give me these bytes of that chunk" from one of a
set of *tiers* — local disk, a peer server's host cache, a remote
registry — each with a configured bandwidth. The bytes are real (read
from disk or an in-memory mirror); the *transfer time* is accounted on a
simulated clock by ``FetchSchedule``, which consumes the Algorithm-2
``ContentionTracker`` fair shares so concurrent cold starts on one
server contend exactly like the paper says they do (Eq. 4: every fetch
completion is a bandwidth-change event; the tracker's iterative settle
provides the per-interval share).

A fetch flow's rate at any instant is ``min(tier_bandwidth, fair_share)``.
Tier-capped flows consume less than their fair share; the tracker's Eq. 4
bookkeeping then retires them early, which redistributes the slack to the
uncapped survivors — the physical behaviour of a flow bottlenecked away
from the NIC.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.placement import ContentionTracker
from repro.core.types import GB, Gbps, ServerSpec
from repro.store.manifest import (CHUNK_DIR, ChunkRecord, Manifest,
                                  build_manifest, load_manifest, save_model)

# Default tier bandwidths (bytes/s): local NVMe readback, a peer server's
# host cache over the 16 Gbps testbed NIC, a remote object registry.
LOCAL_BW = 12e9
PEER_BW = 16 * Gbps
REMOTE_BW = 2 * Gbps

_DONE_EPS = 1e-6


# --------------------------------------------------------------------- tiers
class StoreTier:
    """One source of model bytes: a name, a bandwidth for the simulated
    transfer leg, and a byte-range reader."""

    def __init__(self, name: str, bandwidth: float):
        self.name = name
        self.bandwidth = float(bandwidth)

    def read(self, chunk: ChunkRecord, offset: int, length: int) -> bytes:
        raise NotImplementedError


class DiskTier(StoreTier):
    """Chunks on a filesystem — used for local disk, and (at a different
    bandwidth) as the backing of peer / remote-registry tiers."""

    def __init__(self, name: str, root: str, bandwidth: float):
        super().__init__(name, bandwidth)
        self.root = root

    def read(self, chunk: ChunkRecord, offset: int, length: int) -> bytes:
        path = os.path.join(self.root, CHUNK_DIR, chunk.file)
        with open(path, "rb") as f:
            f.seek(offset)
            data = f.read(length)
        if len(data) != length:
            raise IOError(f"short read of {chunk.file}: wanted {length} "
                          f"bytes at {offset}, got {len(data)}")
        return data


class MemoryTier(StoreTier):
    """Raw chunk bytes held in host memory — the ``from_params`` path
    (and the model of a warm peer's host cache when given a finite bw)."""

    def __init__(self, name: str, blobs: Dict[str, bytes],
                 bandwidth: float = math.inf):
        super().__init__(name, bandwidth)
        self._blobs = blobs

    def read(self, chunk: ChunkRecord, offset: int, length: int) -> bytes:
        return self._blobs[chunk.file][offset:offset + length]


class AliasTier(StoreTier):
    """A placement of the same bytes at a different bandwidth: reads are
    served by the backing tier, only the simulated transfer leg differs.
    This is what Alg. 1 proactive model distribution creates — 'the model
    is now resident on a nearby server group' without duplicating data."""

    def __init__(self, name: str, base: StoreTier, bandwidth: float):
        super().__init__(name, bandwidth)
        self.base = base

    def read(self, chunk: ChunkRecord, offset: int, length: int) -> bytes:
        return self.base.read(chunk, offset, length)


# ------------------------------------------------------------ fetch schedule
@dataclass
class FetchFlow:
    """One in-flight stage fetch on the simulated clock. ``segments`` is
    the piecewise-constant rate profile the fluid model produced — enough
    to answer "when had byte k arrived?" at tensor granularity."""
    server_id: str
    worker_id: str
    size: float
    cap: float
    start: float
    pending: float = 0.0
    segments: List[Tuple[float, float, float]] = field(default_factory=list)
    end: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.end is not None

    @property
    def seconds(self) -> float:
        assert self.end is not None
        return self.end - self.start

    def time_at_bytes(self, nbytes: float) -> float:
        """Arrival instant of the ``nbytes``-th byte (cumulative)."""
        if nbytes <= 0:
            return self.start
        assert self.done, "resolve the flow first"
        cum = 0.0
        for t0, t1, rate in self.segments:
            got = rate * (t1 - t0)
            if cum + got >= nbytes - _DONE_EPS:
                return t0 + (nbytes - cum) / rate if rate > 0 else t1
            cum += got
        return self.end


@dataclass
class _ServerQueue:
    clock: float = 0.0
    flows: List[FetchFlow] = field(default_factory=list)


class FetchSchedule:
    """Simulated-clock fluid model of concurrent cold-start fetches.

    Admissions register with the ``ContentionTracker`` (so Algorithm 2's
    Eq. 3 admission checks see the load) and each event interval's share
    comes from ``tracker.fair_share``; flow completions are reported back
    as bandwidth-change events. Contention is modeled among flows that
    coexist *before resolution* — admit every concurrent flow first,
    then resolve (``StreamedStageLoader.load_group`` does this for the
    stages of one cold start). Resolved flows are frozen history: a
    fetch admitted after another was resolved runs against an idle NIC,
    not retroactively alongside it.
    """

    def __init__(self, tracker: ContentionTracker):
        self.tracker = tracker
        self._queues: Dict[str, _ServerQueue] = {}

    @staticmethod
    def single(bandwidth: float, server_id: str = "local") -> "FetchSchedule":
        """A standalone one-server schedule (store unit tests, loaders
        outside a cluster): NIC bandwidth == the given bandwidth."""
        spec = ServerSpec(server_id, float(bandwidth), 12e9, 1024 * GB)
        return FetchSchedule(ContentionTracker({server_id: spec}))

    # ------------------------------------------------------------- internals
    def _queue(self, server_id: str) -> _ServerQueue:
        return self._queues.setdefault(server_id, _ServerQueue())

    def _step(self, q: _ServerQueue, server_id: str):
        """Advance to the next completion event under the current shares."""
        t = q.clock
        share = self.tracker.fair_share(server_id, t)
        rates = [min(f.cap, share) for f in q.flows]
        dt = min(f.pending / r if r > 0 else math.inf
                 for f, r in zip(q.flows, rates))
        assert math.isfinite(dt), "stalled fetch flow (zero bandwidth)"
        t1 = t + dt
        # a residual below the clock's float resolution (t + dt == t)
        # cannot advance time: finish the minimal flows right here
        # instead of spinning
        force = t1 <= t
        still: List[FetchFlow] = []
        for f, r in zip(q.flows, rates):
            if t1 > t:
                f.segments.append((t, t1, r))
            f.pending -= r * dt
            if f.pending <= _DONE_EPS or \
                    (force and r > 0
                     and f.pending / r <= dt * (1 + 1e-9) + 1e-18):
                f.end = t1
                self.tracker.complete(server_id, f.worker_id, t1)
            else:
                still.append(f)
        q.flows = still
        q.clock = t1

    # --------------------------------------------------------------- public
    def admit(self, server_id: str, worker_id: str, nbytes: float,
              now: float = 0.0, cap: float = math.inf,
              deadline: float = math.inf) -> FetchFlow:
        """Start a fetch of ``nbytes`` on ``server_id``'s NIC at ``now``,
        capped at the source tier's bandwidth. An idle server (no active
        flows) accepts any ``now`` — its NIC has no history to preserve,
        so a later cold start's clock restarts at its own ``now``; while
        flows are in flight the start is clamped to the frozen event
        clock (resolved history cannot be rewritten)."""
        q = self._queue(server_id)
        if not q.flows:
            q.clock = now
        start = max(now, q.clock)
        flow = FetchFlow(server_id, worker_id, float(nbytes), float(cap),
                         start, pending=float(nbytes))
        if nbytes <= 0:
            flow.end = start
            return flow
        self.tracker.admit(server_id, worker_id, nbytes, deadline, start)
        q.flows.append(flow)
        return flow

    def resolve(self, flow: FetchFlow) -> FetchFlow:
        """Run the fluid model until ``flow`` completes."""
        q = self._queue(flow.server_id)
        while not flow.done:
            self._step(q, flow.server_id)
        return flow

    def transfer(self, server_id: str, worker_id: str, nbytes: float,
                 now: float = 0.0, cap: float = math.inf) -> FetchFlow:
        """Admit + resolve in one call (single transfers: consolidation's
        weight fill-in, KV migration)."""
        return self.resolve(self.admit(server_id, worker_id, nbytes, now,
                                       cap))


# ----------------------------------------------------------------- the store
class ModelStore:
    """A chunked model plus the ordered tiers its bytes can come from
    (fastest first). ``tier(name)`` / ``source`` pick where a fetch is
    served from; the byte content is identical across tiers — only the
    simulated transfer bandwidth differs."""

    def __init__(self, manifest: Manifest, tiers: List[StoreTier]):
        assert tiers, "a ModelStore needs at least one tier"
        self.manifest = manifest
        self.tiers = list(tiers)

    # ---------------------------------------------------------- constructors
    @staticmethod
    def open(directory: str, local_bw: float = LOCAL_BW,
             peer_bw: Optional[float] = PEER_BW,
             remote_bw: Optional[float] = REMOTE_BW) -> "ModelStore":
        """Open an on-disk store written by ``save_model``. The same chunk
        files back all three tiers; peer/remote model fetching the bytes
        over the network at their configured bandwidths."""
        manifest = load_manifest(directory)
        tiers: List[StoreTier] = [DiskTier("local", directory, local_bw)]
        if peer_bw is not None:
            tiers.append(DiskTier("peer", directory, peer_bw))
        if remote_bw is not None:
            tiers.append(DiskTier("remote", directory, remote_bw))
        return ModelStore(manifest, tiers)

    @staticmethod
    def save(directory: str, model, params, degrees=None,
             **open_kw) -> "ModelStore":
        save_model(directory, model, params, degrees)
        return ModelStore.open(directory, **open_kw)

    @staticmethod
    def from_params(model, params, degrees=None,
                    bandwidth: float = math.inf) -> "ModelStore":
        """The in-memory path: chunk the live tree into host-memory blobs
        (one 'memory' tier). Default bandwidth is infinite — transfer time
        is then bounded only by the NIC fair share."""
        manifest, arrays = build_manifest(model, params, degrees)
        blobs = {fname: arr.tobytes() for fname, arr in arrays.items()}
        return ModelStore(manifest, [MemoryTier("memory", blobs, bandwidth)])

    # --------------------------------------------------------------- queries
    @property
    def total_bytes(self) -> int:
        return self.manifest.total_bytes

    def stage_bytes(self, s: int, stage: int) -> int:
        return self.manifest.stage_bytes(s, stage)

    def stage_plan(self, s: int, stage: int):
        return self.manifest.stage_plan(s, stage)

    def tier(self, name: Optional[str] = None) -> StoreTier:
        if name is None:
            return self.tiers[0]
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"no tier {name!r} (have "
                       f"{[t.name for t in self.tiers]})")

    # ------------------------------------------------------ tier placement
    def has_tier(self, name: str) -> bool:
        return any(t.name == name for t in self.tiers)

    def fastest_tier(self) -> StoreTier:
        return max(self.tiers, key=lambda t: t.bandwidth)

    def add_tier(self, tier: StoreTier) -> StoreTier:
        """Register a tier, keeping the list sorted fastest-first (so the
        default ``tier(None)`` pick is the best placement we have)."""
        if self.has_tier(tier.name):
            raise ValueError(f"tier {tier.name!r} already exists")
        self.tiers.append(tier)
        self.tiers.sort(key=lambda t: -t.bandwidth)
        return tier

    def place(self, name: str, bandwidth: float,
              source: Optional[str] = None) -> StoreTier:
        """Explicit tier placement (Alg. 1 proactive distribution): make
        the model's bytes available under tier ``name`` at ``bandwidth``,
        backed by ``source`` (default: the current slowest tier — the
        authoritative copy). Re-placing an existing name retunes its
        bandwidth in place; the list stays sorted fastest-first."""
        if self.has_tier(name):
            t = self.tier(name)
            t.bandwidth = float(bandwidth)
            self.tiers.sort(key=lambda t: -t.bandwidth)
            return t
        base = self.tier(source) if source is not None else \
            min(self.tiers, key=lambda t: t.bandwidth)
        return self.add_tier(AliasTier(name, base, bandwidth))

    def drop_tier(self, name: str):
        """Un-place a tier (scale-to-zero of a placement). The last tier
        can never be dropped — the model must stay fetchable."""
        t = self.tier(name)
        if len(self.tiers) == 1:
            raise ValueError("cannot drop the only tier")
        for other in self.tiers:
            if other is not t and isinstance(other, AliasTier) \
                    and other.base is t:
                raise ValueError(
                    f"tier {name!r} still backs placement {other.name!r}")
        self.tiers.remove(t)

    # ---------------------------------------------------------------- reads
    def read_range(self, chunk: ChunkRecord, offset: int, length: int,
                   tier: Optional[str] = None) -> np.ndarray:
        """Materialize a byte range of a chunk as a flat host array."""
        from repro.store.manifest import _np_dtype
        data = self.tier(tier).read(chunk, offset, length)
        return np.frombuffer(data, dtype=_np_dtype(chunk.dtype))
