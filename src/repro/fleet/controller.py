"""Fleet control plane — the shared multi-model scaling policy.

HydraServe's headline numbers are fleet-level: many models contend for
one GPU pool, and what matters is the *distribution* of cold-start
latency and SLO attainment across them. ``FleetController`` is the one
policy implementation both data planes drive:

  * the discrete-event ``ServerlessSim`` (serving/simulation.py), and
  * the real-JAX ``FleetFrontend`` (fleet/frontend.py).

It is deliberately clock-agnostic (every decision takes ``now``) and
holds no data-plane state of its own — hosts pass the live queue /
capacity / at-zero facts in, and get explicit decisions back:

  * ``cold_start_plan``   — demand-driven upscale: how many pipeline
    groups to launch for a model whose queue outruns its in-flight
    capacity, sized by the §6.1 predictor through the
    ``ConsolidationPolicy`` (target-QPS upscale: workers =
    (queue + predicted arrivals) / per-worker capacity).
  * ``keepalive``         — scale-to-zero with *delayed downscale*: the
    idle-reap window stretches while the ``SlidingWindowPredictor``
    still sees demand or the next predicted burst lands inside the
    extension.
  * ``prewarm_due``       — demand-predictive prewarming: per-model
    burst episodes are tracked on top of the sliding-window predictor;
    once a recurrence period is established, a model at zero is
    prewarmed one cold-start-lead before the next predicted episode.
  * ``placement_round``   — Alg. 1 proactive model distribution: the
    demand-ranked hottest models are pre-seeded onto fast fetch tiers
    of chosen servers (``CentralController.plan_distribution`` picks,
    the fleet-wide ``placements`` registry records, the host executes —
    a host-cache fetch in the sim, a ``ModelStore.place`` tier in the
    real data plane). ``preferred_servers`` then biases Alg. 1 scheme
    selection toward the seeded servers.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.controller import CentralController

__all__ = ["FleetPolicy", "FleetController", "LaunchPlan",
           "PlacementAction"]


@dataclass
class FleetPolicy:
    """Knobs of the fleet control plane. ``naive()`` turns every
    proactive mechanism off (the scale-by-demand-only baseline);
    ``proactive()`` is the HydraServe-style configuration."""

    keepalive_s: float = 300.0          # base idle window before reap
    downscale_extend_s: float = 0.0     # max extra keep-alive under demand
    prewarm: bool = False               # predictive prewarming on/off
    prewarm_lead_s: Optional[float] = None   # None = auto from profile
    prewarm_min_burst: int = 1          # observed episode size to justify it
    proactive_placement: bool = False   # Alg. 1 model distribution on/off
    placement_top_k: int = 4            # hottest models to pre-seed
    placement_fanout: int = 2           # servers per pre-seeded model
    placement_interval_s: float = 30.0  # distribution rounds cadence
    placement_tier: str = "peer"        # tier name a placement creates
    episode_gap_s: float = 10.0         # arrival gap that splits episodes
    pulse_s: float = 1.0                # host control-loop cadence

    @staticmethod
    def naive(keepalive_s: float = 300.0) -> "FleetPolicy":
        return FleetPolicy(keepalive_s=keepalive_s)

    @staticmethod
    def proactive(keepalive_s: float = 300.0,
                  downscale_extend_s: float = 120.0,
                  **kw) -> "FleetPolicy":
        return FleetPolicy(keepalive_s=keepalive_s,
                           downscale_extend_s=downscale_extend_s,
                           prewarm=True, proactive_placement=True, **kw)


@dataclass
class _Demand:
    """Per-model burst bookkeeping layered over the sliding window: the
    predictor says *how much* demand a window held, episodes say *when*
    the next burst should land."""
    last_arrival: float = -math.inf
    episode_start: float = -math.inf
    episode_size: int = 0
    last_episode_size: int = 0
    period_ema: Optional[float] = None
    n_episodes: int = 0
    total: int = 0


@dataclass(frozen=True)
class LaunchPlan:
    """One model's scaling decision for this tick."""
    model: str
    n_groups: int           # pipeline groups to cold-start now
    mode: str               # consolidation mode for them: down|up|none
    reason: str             # demand | prewarm

    def __bool__(self) -> bool:
        return self.n_groups > 0


@dataclass(frozen=True)
class PlacementAction:
    """Pre-seed ``model`` onto ``server_id``'s ``tier`` (host executes)."""
    model: str
    server_id: str
    tier: str


class FleetController:
    """Shared fleet scaling policy over a ``CentralController``. One
    instance per cluster; both the sim and the real frontend consult it
    so there is exactly one implementation of the scaling logic."""

    def __init__(self, central: CentralController,
                 policy: Optional[FleetPolicy] = None):
        self.central = central
        self.policy = policy or FleetPolicy()
        self._demand: Dict[str, _Demand] = {}
        self._last_placement = -math.inf
        self._last_prewarm: Dict[str, float] = {}

    # ------------------------------------------------------- demand signal
    def record_arrival(self, model: str, now: float):
        """Feed one request arrival: the sliding-window predictor gets the
        sample and the episode tracker updates its period estimate."""
        self.central.record_request(model, now)
        d = self._demand.setdefault(model, _Demand())
        d.total += 1
        if now - d.last_arrival > self.policy.episode_gap_s:
            if math.isfinite(d.episode_start):
                period = now - d.episode_start
                d.period_ema = period if d.period_ema is None else \
                    0.5 * d.period_ema + 0.5 * period
            d.n_episodes += 1
            d.last_episode_size = d.episode_size
            d.episode_size = 0
            d.episode_start = now
        d.episode_size += 1
        d.last_arrival = now

    def predicted_next_episode(self, model: str,
                               now: float) -> Optional[float]:
        """Next burst instant from the episode period (None until two
        episodes established a period). Missed predictions roll forward
        whole periods so the estimate never trails ``now``."""
        d = self._demand.get(model)
        if d is None or d.period_ema is None or d.period_ema <= 0:
            return None
        k = max(1, math.ceil((now - d.episode_start) / d.period_ema))
        return d.episode_start + k * d.period_ema

    def demand_rank(self, now: float) -> List[str]:
        """Models ranked hottest-first: trailing-window arrivals, then
        last burst size, then lifetime volume (deterministic tiebreak by
        name)."""
        def key(item):
            name, d = item
            window = self.central.predictor.predicted_next_window(name, now)
            return (-window, -max(d.last_episode_size, d.episode_size),
                    -d.total, name)
        ranked = sorted(self._demand.items(), key=key)
        return [name for name, d in ranked if d.total > 0]

    # -------------------------------------------------- scaling decisions
    def cold_start_plan(self, model: str, queue_len: int, capacity: int,
                        current: int, now: float,
                        reason: str = "demand") -> LaunchPlan:
        """Demand-driven upscale: nothing while in-flight capacity covers
        the queue; otherwise the §6.1 consolidation policy sizes the
        launch (scale-up bursts create several groups at once)."""
        if queue_len == 0 or queue_len <= capacity:
            return LaunchPlan(model, 0, "none", reason)
        plan = self.central.consolidation_plan(model, queue_len, now,
                                               current)
        n = max(1, len(plan.group_sizes)) if plan.mode == "up" else 1
        return LaunchPlan(model, n, plan.mode, reason)

    def keepalive(self, model: str, now: float) -> float:
        """Idle window before an endpoint is reaped to zero. Delayed
        downscale: while the predictor still sees demand, or the next
        predicted episode lands within the extension, the window
        stretches (never beyond ``keepalive_s + downscale_extend_s``)."""
        base = self.policy.keepalive_s
        extend = self.policy.downscale_extend_s
        if extend <= 0:
            return base
        cap = base + extend
        want = base
        if self.central.predictor.predicted_next_window(model, now) > 0:
            want = cap
        nxt = self.predicted_next_episode(model, now)
        if nxt is not None and now < nxt:
            want = max(want, (nxt - now) + self.policy.pulse_s)
        return min(want, cap)

    def _prewarm_lead(self, model: str) -> float:
        """How early to launch a prewarm: the expected cold-start span
        (runtime init + the widest pipeline's per-stage fetch on the
        fattest NIC), unless the policy pins a lead."""
        if self.policy.prewarm_lead_s is not None:
            return self.policy.prewarm_lead_s
        prof = self.central.models.get(model)
        if prof is None:
            return 10.0
        nic = max(s.nic_bytes_per_s for s in self.central.servers.values())
        return prof.timings.t_c + prof.size_bytes / max(prof.max_pp, 1) / nic

    def prewarm_due(self, now: float,
                    at_zero: Callable[[str], bool]) -> List[LaunchPlan]:
        """Predictive prewarming: models currently scaled to zero whose
        next predicted episode is within one cold-start lead get a
        single proactive group each. ``at_zero`` is the host's truth
        about the data plane (no replicas live or starting)."""
        if not self.policy.prewarm:
            return []
        out: List[LaunchPlan] = []
        for model, d in self._demand.items():
            if d.n_episodes < 2 or not at_zero(model):
                continue
            if max(d.last_episode_size, d.episode_size) \
                    < self.policy.prewarm_min_burst:
                continue
            nxt = self.predicted_next_episode(model, now)
            if nxt is None:
                continue
            # stale pattern: a predicted episode came and went with no
            # arrivals — stop prewarming until traffic re-establishes it
            if now - d.last_arrival > 1.5 * d.period_ema:
                continue
            lead = self._prewarm_lead(model)
            if not (nxt - lead <= now <= nxt + lead):
                continue
            # one prewarm per predicted episode: a reaped prewarm must not
            # refire for the same prediction
            if self._last_prewarm.get(model, -math.inf) >= nxt - lead:
                continue
            self._last_prewarm[model] = now
            out.append(LaunchPlan(model, 1, "down", "prewarm"))
        return out

    # ------------------------------------------------ proactive placement
    def placement_round(self, now: float) -> List[PlacementAction]:
        """Alg. 1 proactive model distribution, one round per interval:
        rank models by demand, let the central controller spread the top
        K over placement targets, record the seedings fleet-wide, and
        hand the new ones to the host to execute."""
        if not self.policy.proactive_placement:
            return []
        if now - self._last_placement < self.policy.placement_interval_s:
            return []
        self._last_placement = now
        ranked = self.demand_rank(now)[: self.policy.placement_top_k]
        new = self.central.plan_distribution(ranked,
                                             self.policy.placement_fanout)
        tier = self.policy.placement_tier
        for model, sid in new:
            self.central.record_placement(model, sid, tier=tier)
        return [PlacementAction(model, sid, tier) for model, sid in new]

    def preferred_servers(self, model: str) -> List[str]:
        """Placement-aware cold-start bias: the servers this model is
        pre-seeded on (pass as ``plan_cold_start(prefer=...)``)."""
        return self.central.placed_servers(model)
