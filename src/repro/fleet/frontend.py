"""The real-engine fleet data plane: N models, one shared pool.

``FleetFrontend`` is what the single-model ``ServerlessFrontend`` grew
into — a multi-model cluster frontend whose *decisions* all come from
the shared ``FleetController`` (fleet/controller.py) and whose *data
plane* is the real one: every cold start streams stage parameters out
of the model's ``ModelStore`` through the cluster-shared
``FetchSchedule`` (concurrent launches on one server contend per
Alg. 2), engines are real JAX engines, and scale-to-zero round trips
are bit-exact because a re-started endpoint reads the same bytes the
first one did.

Time is the simulated cold-start clock the store data plane already
uses: callers drive a trace through ``advance(now)`` / ``submit(...)``
/ ``pump(now)``, and the frontend executes reaps, prewarms and
placement rounds at the policy's pulse cadence. Engine *compute* is
treated as instantaneous on that clock (the real forward passes run at
wall speed); TTFT estimates combine the measured cold-start wait with
the profile's analytic prefill term, matching the discrete-event sim's
convention.

Lifecycle of a managed model:

    zero --(demand/prewarm launch)--> starting --(timeline.ready)-->
    active --(idle past FleetController.keepalive)--> zero

Requests submitted while ``starting`` queue on the frontend and flush
into the engine the moment the measured timeline says the endpoint is
ready; requests finding a ready endpoint are served warm.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Union

from repro.configs.base import ModelConfig
from repro.core.controller import CentralController
from repro.core.types import ModelProfile, ServerSpec
from repro.fleet.controller import (FleetController, FleetPolicy,
                                    LaunchPlan)
from repro.models import build_model
from repro.router import KVBlockStore, Router
from repro.serving.api import SamplingParams
from repro.serving.endpoint import (PendingColdStart, ServerlessFrontend,
                                    ServingEndpoint)
from repro.store.store import ModelStore, PEER_BW, REMOTE_BW

__all__ = ["FleetFrontend", "FleetRequest", "ManagedModel"]


@dataclass
class FleetRequest:
    """One fleet request and how it fared."""
    rid: int
    model: str
    prompt: Sequence[int]
    params: Optional[SamplingParams]
    arrival: float
    wait: Optional[float] = None        # queued seconds until an engine
    ttft: Optional[float] = None        # wait + analytic prefill estimate
    slo_ok: Optional[bool] = None
    cold: bool = False                  # arrived with no ready endpoint
    output: Optional[List[int]] = None  # generated token ids (real engine)
    replica: Optional[str] = None       # routed endpoint (KV-aware router)
    cached_tokens: int = 0              # prompt prefix served from KV cache
    restored_tokens: int = 0            # ...of which restored from a tier
    restore_seconds: float = 0.0        # modeled restore transfer time


@dataclass
class _Slot:
    """One live endpoint of a model (a replica)."""
    endpoint: ServingEndpoint
    ready_at: float
    mode: str                           # consolidation mode: down|up|none
    reason: str                         # demand | prewarm
    idle_since: Optional[float] = None
    consolidated: bool = False
    name: str = ""                      # stable replica id (router key)


@dataclass
class ManagedModel:
    name: str
    cfg: ModelConfig
    profile: ModelProfile
    base_tier: str                      # authoritative (slowest) tier
    engine_kw: dict
    slots: List[_Slot] = field(default_factory=list)
    queue: Deque[FleetRequest] = field(default_factory=collections.deque)
    router: Optional[Router] = None     # KV-aware replica routing, if on
    kv_tier: Optional[KVBlockStore] = None   # shared spill/restore tiers
    n_launched: int = 0                 # replica name counter

    @property
    def state(self) -> str:
        if not self.slots:
            return "zero"
        return "active" if any(s.ready_at is not None for s in self.slots) \
            else "starting"

    def ready_slots(self, now: float) -> List[_Slot]:
        return [s for s in self.slots if s.ready_at <= now]


class FleetFrontend:
    """Multi-model cluster frontend over one shared server pool. All
    scaling decisions come from the shared ``FleetController``; all
    cold-start bytes move through the per-model ``ModelStore``s on the
    one cluster ``FetchSchedule``."""

    def __init__(self, servers: Union[Dict[str, ServerSpec],
                                      Sequence[ServerSpec]],
                 policy: Optional[FleetPolicy] = None,
                 controller: Optional[CentralController] = None,
                 source_bw: float = REMOTE_BW,
                 placement_bw: float = PEER_BW,
                 **controller_kw):
        if not isinstance(servers, dict):
            servers = {s.server_id: s for s in servers}
        self.frontend = ServerlessFrontend(servers, controller,
                                           **controller_kw)
        self.central = self.frontend.controller
        self.fleet = FleetController(self.central, policy)
        self.policy = self.fleet.policy
        self.source_bw = float(source_bw)
        self.placement_bw = float(placement_bw)
        self.models: Dict[str, ManagedModel] = {}
        self.requests: List[FleetRequest] = []
        self.cold_start_log: List[dict] = []
        self.placement_log: List[dict] = []
        self.now = 0.0
        self._rid = 0
        self._last_pulse = 0.0

    # ----------------------------------------------------------- registry
    def register(self, cfg: ModelConfig, profile: ModelProfile, *,
                 params: Optional[dict] = None,
                 store: Optional[ModelStore] = None,
                 store_dir: Optional[str] = None,
                 routing: Optional[str] = None,
                 kv_tier_blocks: Optional[int] = None,
                 routing_kw: Optional[dict] = None,
                 **engine_kw) -> ManagedModel:
        """Register a model with the fleet, starting at zero replicas.
        ``params`` chunks the live tree behind a ``source_bw``-limited
        tier (the 'remote registry' a never-distributed model fetches
        from); ``store``/``store_dir`` follow ``ServerlessFrontend.deploy``
        — including the cold-deploy path (``params=None`` with an
        existing on-disk store).

        ``routing`` turns on the KV-aware routing subsystem for this
        model: a per-model ``Router`` (policy name or instance,
        ``routing_kw`` forwarded to it) over a shared ``KVBlockStore``
        whose host tier holds at most ``kv_tier_blocks`` live blocks
        (``None`` = unbounded) before demoting to the segment tier.
        Routed models are forced paged + prefix-cached so evicted
        blocks spill instead of vanishing."""
        if store is None and params is not None and store_dir is None:
            store = ModelStore.from_params(build_model(cfg), params,
                                           bandwidth=self.source_bw)
        store = self.frontend.deploy(cfg, params, profile, store=store,
                                     store_dir=store_dir)
        base = min(store.tiers, key=lambda t: t.bandwidth).name
        mm = ManagedModel(profile.name, cfg, profile, base, dict(engine_kw))
        if routing is not None:
            server0 = next(iter(self.frontend.servers), "local")
            mm.kv_tier = KVBlockStore(
                self.frontend.schedule, server0,
                host_capacity_blocks=kv_tier_blocks)
            mm.router = Router(routing, kv_tier=mm.kv_tier,
                               **(routing_kw or {}))
            mm.engine_kw.setdefault("paged", True)
            mm.engine_kw.setdefault("prefix_cache", True)
            mm.engine_kw["kv_tier"] = mm.kv_tier
        self.models[profile.name] = mm
        return mm

    # ------------------------------------------------------------ serving
    def submit(self, model: str, prompt: Sequence[int],
               params: Optional[SamplingParams] = None, *,
               now: Optional[float] = None,
               pump: bool = True) -> FleetRequest:
        """Submit a request at simulated instant ``now``. A ready
        endpoint serves it warm; otherwise it queues for the model's
        cold start (``pump=False`` lets a caller batch several same-tick
        submissions so the resulting launches contend on the NICs — done
        automatically by ``run_trace``)."""
        now = self.now if now is None else now
        self.advance(now)
        mm = self.models[model]
        self.fleet.record_arrival(model, now)
        req = FleetRequest(self._rid, model, list(prompt), params, now,
                           cold=not mm.ready_slots(now))
        self._rid += 1
        self.requests.append(req)
        mm.queue.append(req)
        if pump:
            self.pump(now)
        return req

    def pump(self, now: Optional[float] = None):
        """One fleet scheduling round: collect every model's demand
        launch decision, *begin* all resulting cold starts (their
        fetches contend on the shared schedule), then finish them and
        flush what became ready."""
        now = self.now if now is None else max(now, self.now)
        self.now = now
        plans = []
        for mm in self.models.values():
            plan = self.fleet.cold_start_plan(
                mm.name, len(mm.queue), self._capacity(mm),
                len(mm.slots), now)
            if plan:
                plans.append(plan)
        self._launch(plans, now)
        self._flush(now)

    def advance(self, to: float):
        """Advance the simulated clock, running the control loop at the
        policy's pulse cadence: placement rounds, predictive prewarms,
        ready-queue flushes, idle consolidation and scale-to-zero reaps."""
        to = max(to, self.now)
        pulse = max(self.policy.pulse_s, 1e-6)
        while self._last_pulse + pulse <= to:
            self._last_pulse += pulse
            self._tick(self._last_pulse)
        self.now = to
        self._flush(to)

    def run_trace(self, trace, *, drain_to: Optional[float] = None
                  ) -> List[FleetRequest]:
        """Drive (model, arrival, prompt[, params]) records in time
        order; same-instant arrivals are batched into one pump so their
        cold starts contend. ``drain_to`` advances the clock afterwards
        (keepalive reaps included)."""
        out = []
        items = sorted(trace, key=lambda r: r[1])
        i = 0
        while i < len(items):
            t = items[i][1]
            self.advance(t)
            while i < len(items) and items[i][1] == t:
                model, _, prompt = items[i][:3]
                params = items[i][3] if len(items[i]) > 3 else None
                out.append(self.submit(model, prompt, params, now=t,
                                       pump=False))
                i += 1
            self.pump(t)
        if drain_to is not None:
            self.advance(drain_to)
        return out

    def scale_to(self, model: str, n: int,
                 now: Optional[float] = None) -> ManagedModel:
        """Launch demand replicas until ``model`` has ``n`` slots (never
        scales down — the keepalive reaper owns that). Handy for benches
        that want a fixed replica fan before driving a trace."""
        now = self.now if now is None else max(now, self.now)
        self.now = now
        mm = self.models[model]
        while len(mm.slots) < n:
            self._launch([LaunchPlan(model, 1, "none", "demand")], now)
        return mm

    # ---------------------------------------------------------- internals
    def _capacity(self, mm: ManagedModel) -> int:
        cap = self.central.consolidation.per_worker_capacity
        return cap * len(mm.slots)

    def _at_zero(self, model: str) -> bool:
        mm = self.models[model]
        return not mm.slots and not mm.queue

    def _launch(self, plans: List[LaunchPlan], now: float):
        pending: List[tuple] = []
        for plan in plans:
            mm = self.models[plan.model]
            for _ in range(plan.n_groups):
                p = self.frontend.begin_cold_start(
                    plan.model, now=now,
                    prefer=self.fleet.preferred_servers(plan.model),
                    fallback_tier=mm.base_tier, **mm.engine_kw)
                pending.append((plan, p))
        for plan, p in pending:
            self._finish_launch(plan, p, now)

    def _finish_launch(self, plan: LaunchPlan, p: PendingColdStart,
                       now: float):
        mm = self.models[plan.model]
        ep = p.finish()
        ready = ep.cold_start_timeline.ready
        slot = _Slot(ep, ready, plan.mode, plan.reason, idle_since=ready,
                     name=f"{plan.model}/r{mm.n_launched}")
        mm.n_launched += 1
        mm.slots.append(slot)
        if mm.router is not None:
            mm.router.register(slot.name, ep)
            mm.router.set_pending(slot.name, ready > now)
        self.cold_start_log.append({
            "model": plan.model, "t0": now, "ready": ready,
            "duration": ready - now, "reason": plan.reason,
            "s": ep.cold_start_timeline.s,
            "tier": ep.cold_start_timeline.stages[0].tier,
            "servers": list(ep.scheme.servers) if ep.scheme else [],
        })

    def _tick(self, t: float):
        for act in self.fleet.placement_round(t):
            store = self.frontend.store_of(act.model)
            store.place(act.tier, self.placement_bw)
            self.placement_log.append({
                "model": act.model, "server": act.server_id,
                "tier": act.tier, "t": t})
        prewarms = self.fleet.prewarm_due(t, self._at_zero)
        if prewarms:
            self._launch(prewarms, t)
        self._flush(t)
        self._consolidate_idle(t)
        self._reap(t)

    def _flush(self, now: float):
        """Feed queued requests into ready endpoints and run the real
        engines to completion. Router-enabled models pick the replica by
        policy (warm-prefix affinity, saturation overflow) and their
        TTFT estimate discounts the analytic prefill by the measured
        cached fraction, then adds the measured KV-restore transfer."""
        for mm in self.models.values():
            ready = mm.ready_slots(now)
            if not ready or not mm.queue:
                continue
            if mm.kv_tier is not None:
                mm.kv_tier.now = now
            if mm.router is not None:
                for slot in mm.slots:
                    mm.router.set_pending(slot.name, slot.ready_at > now)
            while mm.queue:
                req = mm.queue.popleft()
                slot = self._pick_slot(mm, ready, req)
                handle = slot.endpoint.submit(req.prompt, req.params)
                served_at = max(slot.ready_at, req.arrival)
                req.wait = served_at - req.arrival
                req.replica = slot.name or None
                slot.idle_since = None
                slot.endpoint.run()
                req.output = list(handle.generated)
                est = self._prefill_est(mm, slot)
                if mm.router is not None:
                    # routed models prorate the analytic prefill per
                    # *uncached* token (t_p = full-context prefill), so
                    # the KV the router preserved shows up in TTFT; the
                    # measured restore transfer is paid on top
                    m = handle.metrics
                    req.cached_tokens = m.cached_tokens
                    req.restored_tokens = m.restored_tokens
                    req.restore_seconds = m.restore_seconds
                    ctx = slot.endpoint.engine.max_seq
                    uncached = max(0, len(req.prompt) - m.cached_tokens)
                    est = est * uncached / max(ctx, 1) + m.restore_seconds
                req.ttft = req.wait + est
                req.slo_ok = req.ttft <= mm.profile.slo.ttft + 1e-9
            for slot in ready:
                if not slot.endpoint.has_work() \
                        and slot.idle_since is None:
                    slot.idle_since = now

    def _pick_slot(self, mm: ManagedModel, ready: List[_Slot],
                   req: FleetRequest) -> _Slot:
        if mm.router is not None and len(ready) > 0:
            decision = mm.router.route(req.prompt)
            for slot in ready:
                if slot.name == decision.name:
                    return slot
            # routed to a still-pending replica: serve on a ready one
        return min(ready, key=lambda s: len(s.endpoint.active()))

    def _prefill_est(self, mm: ManagedModel, slot: _Slot) -> float:
        t = mm.profile.timings
        scheme = slot.endpoint.scheme
        s = slot.endpoint.n_stages
        w = scheme.w if scheme else s
        base = t.t_p
        if s <= 1:
            return base
        return base * (s - w + w / s) + t.t_n * s

    def _consolidate_idle(self, t: float):
        """§6.2 merge: an idle pipeline-parallel replica consolidates to
        one standalone worker (weights filled in through the store, KV
        migration accounted as a real flow)."""
        for mm in self.models.values():
            for slot in mm.slots:
                if (slot.ready_at <= t and not slot.consolidated
                        and slot.mode == "down"
                        and slot.endpoint.n_stages > 1
                        and slot.idle_since is not None):
                    self.frontend.consolidate(slot.endpoint, mm.name,
                                              now=t)
                    slot.consolidated = True

    def _reap(self, t: float):
        """Scale-to-zero: idle endpoints past the (demand-extended)
        keep-alive window are retired; their model returns to zero and
        its next request pays a fresh — bit-exact — cold start."""
        for mm in self.models.values():
            keep = self.fleet.keepalive(mm.name, t)
            survivors = []
            for slot in mm.slots:
                idle = slot.idle_since
                if (idle is not None and slot.ready_at <= t
                        and not slot.endpoint.has_work()
                        and t - max(idle, slot.ready_at) >= keep):
                    if mm.kv_tier is not None:
                        # scale-to-zero demotes the replica's whole prefix
                        # cache to the host tier (evict hooks spill) so
                        # the next cold start can restore it
                        mm.kv_tier.now = t
                        slot.endpoint.engine.block_mgr \
                            .drop_unreferenced_cache()
                    if mm.router is not None and slot.name:
                        mm.router.unregister(slot.name)
                    slot.endpoint.engine.retire()
                else:
                    survivors.append(slot)
            mm.slots = survivors

    # ------------------------------------------------------------ metrics
    def metrics(self) -> dict:
        done = [r for r in self.requests if r.ttft is not None]
        if not done:
            return {"n": 0}
        waits = sorted(r.wait for r in done)
        ttfts = sorted(r.ttft for r in done)

        def pct(xs, q):
            return xs[min(len(xs) - 1, int(len(xs) * q))] if xs else 0.0

        cold = [r for r in done if r.cold]
        cold_ttfts = sorted(r.ttft for r in cold)
        durs = sorted(c["duration"] for c in self.cold_start_log)
        return {
            "n": len(done),
            "ttft_attainment": sum(r.slo_ok for r in done) / len(done),
            "ttft_p50": pct(ttfts, 0.50), "ttft_p99": pct(ttfts, 0.99),
            "wait_p50": pct(waits, 0.50), "wait_p99": pct(waits, 0.99),
            "cold_requests": len(cold),
            "cold_p50": pct(cold_ttfts, 0.50),
            "cold_p99": pct(cold_ttfts, 0.99),
            "cold_starts": len(self.cold_start_log),
            "cold_start_p50": pct(durs, 0.50),
            "cold_start_p99": pct(durs, 0.99),
            "prewarms": sum(1 for c in self.cold_start_log
                            if c["reason"] == "prewarm"),
            "placements": len(self.placement_log),
            "per_model": {name: self._model_metrics(mm)
                          for name, mm in self.models.items()},
        }

    def _model_metrics(self, mm: ManagedModel) -> dict:
        done = [r for r in self.requests
                if r.model == mm.name and r.ttft is not None]
        out = {
            "state": mm.state,
            "replicas": [s.name or f"{mm.name}/?" for s in mm.slots],
            "n": len(done),
            "endpoints": {s.name or str(i): s.endpoint.stats()
                          for i, s in enumerate(mm.slots)},
        }
        if mm.router is not None:
            prompt_tokens = sum(len(r.prompt) for r in done)
            out["router"] = mm.router.stats()
            out["kv_tier"] = mm.kv_tier.stats()
            out["cached_tokens"] = sum(r.cached_tokens for r in done)
            out["restored_tokens"] = sum(r.restored_tokens for r in done)
            out["cached_ratio"] = (out["cached_tokens"] / prompt_tokens
                                   if prompt_tokens else 0.0)
        return out
