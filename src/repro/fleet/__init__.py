"""Fleet control plane: multi-model cluster controller shared by the
discrete-event simulation and the real JAX serving path.

``controller`` — ``FleetController``/``FleetPolicy``: the one scaling
                 policy implementation (upscale, scale-to-zero with
                 delayed downscale, predictive prewarming, Alg. 1
                 proactive model distribution);
``frontend``   — ``FleetFrontend``: the real-engine data plane — N
                 registered models over a shared server pool with
                 per-model endpoint lifecycle, request queuing during
                 cold starts, and concurrent contending cold starts.
"""

from repro.fleet.controller import (FleetController, FleetPolicy,
                                    LaunchPlan, PlacementAction)
from repro.fleet.frontend import FleetFrontend, FleetRequest, ManagedModel

__all__ = [
    "FleetController", "FleetPolicy", "LaunchPlan", "PlacementAction",
    "FleetFrontend", "FleetRequest", "ManagedModel",
]
