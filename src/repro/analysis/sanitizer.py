"""KV-lifecycle sanitizer: a shadow BlockManager that audits the pool.

The sanitizer mirrors every KV lifecycle event — allocate / extend /
commit / free / evict / spill / restore / migrate — through the
``tracer`` instrumentation points in ``serving/kvcache.py``,
``serving/runner.py``, ``serving/worker.py``, ``router/kvtier.py`` and
``serving/migration.py``, plus the BlockManager's existing
commit/evict hook channel, and cross-checks each event against its own
shadow state:

  * **use-after-free reads** — a page read (`worker.read_page`, decode,
    ragged forward) of a block no live table references, that is not in
    the prefix index, and that is not inside the evict-notification
    window (the spill hook's legitimate read-at-evict);
  * **reads of unwritten / uncommitted pages** — attention over rows no
    prefill/decode/restore ever materialized, or an index registration
    (``commit``) claiming rows that were never written;
  * **double-free** — ``free`` / ``release_for_preempt`` of a request id
    whose table was already dropped;
  * **refcount drift / leaks** — the shadow per-block refcounts are
    compared against ``BlockManager.refcount`` at every free and (via
    :meth:`check_idle`) at quiescence, when every block must be back to
    refcount zero;
  * **evict-before-notify** (the PR 7 bug class) — a block handed out
    for reuse while the shadow index still maps it: the eviction either
    never fired its hook or fired it after the block id escaped;
  * **byte-accounting drift** — every spill/restore payload and §6.2
    migration gather is measured against the
    ``paged_kv_token_bytes``-derived expectation, and spill→restore
    round trips are content-digest checked (a digest mismatch means the
    spilled bytes were read after the page was reused).

Zero overhead when off: every instrumentation site guards on
``tracer is not None`` and the attribute defaults to ``None`` — the
sanitize-off path executes the exact pre-instrumentation code.

Enable with ``Engine(sanitize=True)`` or ``REPRO_SANITIZE=1``. Findings
accumulate on :attr:`KVSanitizer.findings`; ``strict=True`` raises
``KVInvariantError`` at the first finding instead.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.serving.kvcache import KVInvariantError

__all__ = ["Finding", "KVSanitizer"]


@dataclass(frozen=True)
class Finding:
    """One detected lifecycle violation."""
    kind: str          # e.g. "double-free", "evict-before-notify"
    message: str

    def __str__(self):
        return f"[{self.kind}] {self.message}"


def _payload_digest(payload) -> bytes:
    """Content digest of a spill payload (order- and leaf-stable)."""
    h = hashlib.sha256()
    for entry in payload:
        h.update(str(entry[0]).encode())
        h.update(memoryview(entry[1]).tobytes() if hasattr(entry[1], "tobytes")
                 else bytes(entry[1]))
        h.update(entry[2].tobytes())
        if len(entry) > 3:
            for leaf in sorted(entry[3]):
                h.update(leaf.encode())
                h.update(entry[3][leaf].tobytes())
    return h.digest()


def _payload_nbytes(payload) -> int:
    """Independent byte count of a spill payload (not the store's own)."""
    n = 0
    for entry in payload:
        n += int(entry[1].nbytes) + int(entry[2].nbytes)
        if len(entry) > 3:
            n += sum(int(a.nbytes) for a in entry[3].values())
    return n


class KVSanitizer:
    """Shadow BlockManager; install with :meth:`install`."""

    def __init__(self, n_blocks: int, block_size: int, *,
                 expected_block_bytes: Optional[int] = None,
                 strict: bool = False):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.expected_block_bytes = expected_block_bytes
        self.strict = strict
        self.findings: List[Finding] = []
        self.events = 0
        # ---- shadow state
        self.ref = [0] * n_blocks               # expected refcounts
        self.written = [0] * n_blocks           # materialized rows (high-water)
        self.owner: Dict[int, List[int]] = {}   # rid -> blocks (live tables)
        self.lengths: Dict[int, int] = {}       # rid -> token rows held
        self.freed: Set[int] = set()            # rids free()'d (finished)
        self.released: Set[int] = set()         # rids released for preempt
        self.indexed: Dict[bytes, int] = {}     # prefix-index mirror
        self.indexed_blocks: Dict[int, bytes] = {}
        self.restore_pending: Set[int] = set()  # registered, bytes not landed
        self.grace: Set[int] = set()            # evict-notified, pre-reuse
        self.slot_rows: Dict[int, List[int]] = {}
        self.spill_digests: Dict[bytes, bytes] = {}
        self.last_migration: Optional[Tuple[int, Optional[int]]] = None
        self._bm = None                         # BlockManager, for drift cmp

    # ------------------------------------------------------------ install
    @classmethod
    def install(cls, engine) -> "KVSanitizer":
        """Attach a fresh sanitizer to an engine: shadow the BlockManager
        (tracer + commit/evict hook subscriptions), the runner, every
        stage worker, and the KV tier if one is attached."""
        bm = engine.block_mgr
        san = cls(bm.n_blocks, bm.block_size,
                  expected_block_bytes=(bm.block_size * bm.bytes_per_token
                                        * engine.n_attn_layers()))
        san._bm = bm
        bm.tracer = san
        bm.commit_hooks.append(san._on_index_add)
        bm.evict_hooks.append(san._on_index_drop)
        san.rebind(engine)
        return san

    def rebind(self, engine):
        """Point a successor engine's tracer endpoints at this sanitizer
        (§6.2 consolidation: the shared BlockManager already carries the
        tracer and hooks; the runner/workers/tier are new objects)."""
        self._bm = engine.block_mgr
        engine.block_mgr.tracer = self
        engine.runner.tracer = self
        for w in engine.runner.workers:
            w.tracer = self
        if engine.kv_tier is not None:
            engine.kv_tier.tracer = self

    # ------------------------------------------------------------ reports
    def _find(self, kind: str, message: str):
        f = Finding(kind, message)
        self.findings.append(f)
        if self.strict:
            raise KVInvariantError(str(f))

    def report(self) -> str:
        if not self.findings:
            return f"kv-sanitizer: clean ({self.events} events audited)"
        lines = [f"kv-sanitizer: {len(self.findings)} finding(s) over "
                 f"{self.events} events:"]
        lines += [f"  {f}" for f in self.findings]
        return "\n".join(lines)

    def raise_if_findings(self):
        if self.findings:
            raise KVInvariantError(self.report())

    # --------------------------------------------------- index hook channel
    def _on_index_add(self, blk: int, h: bytes):
        self.events += 1
        old = self.indexed_blocks.get(blk)
        if old is not None and old != h:
            # the block was reused under a new hash while the shadow index
            # still mapped it: its eviction never notified (PR 7 class)
            self._find("evict-before-notify",
                       f"block {blk} re-registered under a new chain hash "
                       f"while still indexed — eviction was not notified")
            self.indexed.pop(old, None)
        self.indexed[h] = blk
        self.indexed_blocks[blk] = h
        self.grace.discard(blk)
        if self.ref[blk] >= 1:
            # engine-driven commit: the rows must already be materialized
            if self.written[blk] < self.block_size \
                    and blk not in self.restore_pending:
                self._find("uncommitted-commit",
                           f"block {blk} entered the prefix index with only "
                           f"{self.written[blk]}/{self.block_size} rows "
                           f"written")
        else:
            # allocate-time restore registration: bytes land later via
            # write_page (Engine._apply_restores) — reads before that are
            # flagged by the written-rows checks
            self.restore_pending.add(blk)
            self.written[blk] = 0

    def _on_index_drop(self, blk: int, h: bytes):
        self.events += 1
        if self.indexed.get(h) == blk:
            del self.indexed[h]
        if self.indexed_blocks.get(blk) == h:
            del self.indexed_blocks[blk]
        # the evict-notification window: the spill hook may still read the
        # page until the block id is handed out again
        self.grace.add(blk)

    # --------------------------------------------------- BlockManager events
    def _acquire_fresh(self, blk: int, what: str):
        """A block id was handed out for new content."""
        if blk in self.indexed_blocks:
            self._find("evict-before-notify",
                       f"block {blk} handed out as {what} while the shadow "
                       f"index still maps it (hash "
                       f"{self.indexed_blocks[blk].hex()[:12]}…) — eviction "
                       f"did not notify before reuse")
            h = self.indexed_blocks.pop(blk)
            self.indexed.pop(h, None)
        self.ref[blk] += 1
        self.written[blk] = 0
        self.restore_pending.discard(blk)
        self.grace.discard(blk)

    def on_alloc(self, rid: int, blocks: List[int], n_tokens: int, *,
                 shared: Sequence[int], restored: Sequence[Tuple[bytes, int]],
                 cow: Sequence[Tuple[int, int]], cached: int):
        self.events += 1
        if rid in self.owner:
            self._find("alloc-live-rid",
                       f"allocate for request {rid} whose table is still "
                       f"live")
        restored_dst = {b for _, b in restored}
        cow_dst = {d for _, d in cow}
        for b in shared:
            if b not in self.indexed_blocks and b not in restored_dst:
                self._find("share-unindexed",
                           f"request {rid} shares block {b} that the shadow "
                           f"prefix index does not map")
            elif (self.written[b] < self.block_size
                  and b not in self.restore_pending):
                self._find("share-unwritten",
                           f"request {rid} shares block {b} with only "
                           f"{self.written[b]}/{self.block_size} rows "
                           f"written")
            self.ref[b] += 1
            self.grace.discard(b)
        for _, b in restored:
            # registered via the commit hook during allocate; the +1 here
            # mirrors the manager's own ref for the new table
            self.ref[b] += 1
            self.grace.discard(b)
        for _, d in cow:
            self._acquire_fresh(d, "a COW destination")
        seen = set(shared) | restored_dst | cow_dst
        for b in blocks:
            if b not in seen:
                self._acquire_fresh(b, "a fresh block")
        self.owner[rid] = list(blocks)
        self.lengths[rid] = n_tokens
        self.freed.discard(rid)
        self.released.discard(rid)

    def on_extend(self, rid: int, new_blocks: List[int], new_len: int):
        self.events += 1
        t = self.owner.get(rid)
        if t is None:
            self._find("extend-unknown-rid",
                       f"extend for request {rid} with no live table")
            return
        for b in new_blocks:
            self._acquire_fresh(b, "an extend block")
            t.append(b)
        self.lengths[rid] = new_len

    def on_commit(self, rid: int, n_valid: int):
        """Check — not mark: ``commit`` *claims* rows [0, n_valid) are
        materialized; the shadow written-rows state was built from the
        actual compute/copy/restore traces, so a claim the traces don't
        back is exactly the uncommitted-page bug."""
        self.events += 1
        t = self.owner.get(rid)
        if t is None:
            return
        bs = self.block_size
        limit = min(n_valid, self.lengths.get(rid, 0))
        for i in range(limit // bs):
            b = t[i]
            if self.written[b] < bs and b not in self.restore_pending:
                self._find("uncommitted-commit",
                           f"commit({rid}, {n_valid}) covers block {b} "
                           f"(chain index {i}) with only {self.written[b]}"
                           f"/{bs} rows written")

    def _release(self, rid: int, blocks: Optional[List[int]], verb: str,
                 registry: Set[int]):
        self.events += 1
        if blocks is None:
            if rid in self.freed or rid in self.released:
                self._find("double-free",
                           f"{verb} of request {rid} whose table was "
                           f"already dropped")
            else:
                self._find("free-unknown",
                           f"{verb} of request {rid} that never held a "
                           f"table")
            return
        expect = self.owner.pop(rid, None)
        self.lengths.pop(rid, None)
        if expect is not None and list(blocks) != expect:
            self._find("table-mismatch",
                       f"{verb} of request {rid} returns blocks {blocks} "
                       f"but the shadow table held {expect}")
        for b in blocks:
            if self._bm is not None and self._bm.refcount(b) != self.ref[b]:
                self._find("refcount-drift",
                           f"block {b} refcount {self._bm.refcount(b)} != "
                           f"shadow {self.ref[b]} at {verb} of request "
                           f"{rid}")
            self.ref[b] -= 1
            if self.ref[b] < 0:
                self._find("refcount-underflow",
                           f"{verb} of request {rid} drops block {b} below "
                           f"refcount zero")
                self.ref[b] = 0
        registry.add(rid)

    def on_free(self, rid: int, blocks: Optional[List[int]]):
        self._release(rid, blocks, "free", self.freed)

    def on_release(self, rid: int, blocks: Optional[List[int]]):
        self._release(rid, blocks, "release_for_preempt", self.released)

    def on_drain_copies(self, pairs: List[Tuple[int, int]]):
        self.events += 1
        for src, _dst in pairs:
            self.ref[src] -= 1
            if self.ref[src] < 0:
                self._find("refcount-underflow",
                           f"COW drain drops source block {src} below "
                           f"refcount zero")
                self.ref[src] = 0

    # ------------------------------------------------------- runner events
    def on_set_row(self, slot: int, blocks: List[int]):
        self.events += 1
        for b in blocks:
            if self.ref[b] <= 0:
                self._find("row-dead-block",
                           f"slot {slot} block-table row names block {b} "
                           f"with shadow refcount {self.ref[b]}")
        self.slot_rows[slot] = list(blocks)

    def on_clear_row(self, slot: int):
        self.events += 1
        self.slot_rows.pop(slot, None)

    def _check_span(self, slot: int, pos0: int, n: int, what: str):
        """Rows [0, pos0) of the slot's chain must be materialized (the
        forward attends to them); rows [pos0, pos0+n) become written."""
        blocks = self.slot_rows.get(slot)
        if blocks is None:
            self._find("compute-dead-slot",
                       f"{what} on slot {slot} with no block-table row")
            return
        bs = self.block_size
        if pos0 + n > len(blocks) * bs:
            self._find("compute-past-table",
                       f"{what} on slot {slot} writes rows "
                       f"[{pos0}, {pos0 + n}) past its {len(blocks)}-block "
                       f"table")
            return
        for i in range((pos0 + bs - 1) // bs):
            b = blocks[i]
            need = min(bs, pos0 - i * bs)
            if self.written[b] < need:
                kind = ("use-after-free-read" if self.ref[b] <= 0
                        and b not in self.indexed_blocks
                        else "unwritten-read")
                self._find(kind,
                           f"{what} on slot {slot} attends rows of block "
                           f"{b} with {self.written[b]}/{need} rows "
                           f"written")
        for p in range(pos0, pos0 + n):
            b = blocks[p // bs]
            self.written[b] = max(self.written[b], p % bs + 1)
            self.restore_pending.discard(b)

    def on_prefill(self, slot: int, start: int, n: int):
        self.events += 1
        self._check_span(slot, start, n, "prefill")

    def on_decode(self, slots_pos: List[Tuple[int, int]],
                  skip_slots: List[int]):
        self.events += 1
        for slot, pos in slots_pos:
            self._check_span(slot, pos, 1, "decode")

    def on_forward_batch(self, segments: List[Tuple[int, int, int]]):
        self.events += 1
        for slot, n, pos0 in segments:
            self._check_span(slot, pos0, n, "ragged forward")

    # ------------------------------------------------------- worker events
    def on_page_read(self, name: str, blk: int, stage: int):
        self.events += 1
        if (self.ref[blk] <= 0 and blk not in self.indexed_blocks
                and blk not in self.grace):
            self._find("use-after-free-read",
                       f"page read of block {blk} ({name}, stage {stage}) "
                       f"that no table, index entry, or evict notification "
                       f"covers")
        elif self.written[blk] < self.block_size \
                and blk not in self.restore_pending:
            self._find("uncommitted-read",
                       f"page read of block {blk} ({name}, stage {stage}) "
                       f"with only {self.written[blk]}/{self.block_size} "
                       f"rows written")

    def on_page_write(self, name: str, blk: int, stage: int):
        self.events += 1
        if self.ref[blk] <= 0 and blk not in self.indexed_blocks:
            self._find("write-unowned",
                       f"page write to block {blk} ({name}, stage {stage}) "
                       f"that no table or index entry owns")
        self.written[blk] = self.block_size
        self.restore_pending.discard(blk)

    def on_copy_pages(self, src: int, dst: int, stage: int):
        self.events += 1
        if self.ref[src] <= 0 and src not in self.indexed_blocks:
            self._find("use-after-free-read",
                       f"COW copy reads source block {src} (stage {stage}) "
                       f"that no table or index entry covers")
        if self.ref[dst] <= 0:
            self._find("write-unowned",
                       f"COW copy writes block {dst} (stage {stage}) with "
                       f"shadow refcount {self.ref[dst]}")
        self.written[dst] = max(self.written[dst], self.written[src])

    # ------------------------------------------------------ KV tier events
    def on_spill(self, h: bytes, payload):
        self.events += 1
        nbytes = _payload_nbytes(payload)
        if (self.expected_block_bytes is not None
                and nbytes != self.expected_block_bytes):
            self._find("byte-drift",
                       f"spill of {h.hex()[:12]}… measured {nbytes} B, "
                       f"paged_kv_token_bytes expects "
                       f"{self.expected_block_bytes} B/block")
        digest = _payload_digest(payload)
        prev = self.spill_digests.get(h)
        if prev is not None and prev != digest:
            self._find("use-after-free-spill",
                       f"re-spill of {h.hex()[:12]}… carries different "
                       f"bytes than its first spill — the page was read "
                       f"after its block id was reused")
        self.spill_digests[h] = digest

    def on_restore_take(self, h: bytes, payload, nbytes: int):
        self.events += 1
        if (self.expected_block_bytes is not None
                and nbytes != self.expected_block_bytes):
            self._find("byte-drift",
                       f"restore of {h.hex()[:12]}… charged {nbytes} B, "
                       f"paged_kv_token_bytes expects "
                       f"{self.expected_block_bytes} B/block")
        prev = self.spill_digests.get(h)
        if prev is not None and _payload_digest(payload) != prev:
            self._find("restore-corruption",
                       f"restore of {h.hex()[:12]}… returns different "
                       f"bytes than were spilled")

    # ------------------------------------------------------ migration event
    def on_migration_gather(self, moved: int, live_blocks: Optional[list],
                            n_stages: int):
        self.events += 1
        self.last_migration = (moved,
                               len(live_blocks)
                               if live_blocks is not None else None)

    def check_migration(self, moved: int, expected: int):
        """§6.2 gather vs ``BlockManager.migration_bytes`` quote."""
        self.events += 1
        if moved != expected:
            self._find("migration-drift",
                       f"§6.2 gather moved {moved} B but the BlockManager "
                       f"quoted {expected} B")

    # --------------------------------------------------------- final audit
    def check_idle(self, bm=None) -> List[Finding]:
        """Quiescence audit — call when the engine reports no work left:
        every table must be gone and every block back at refcount zero,
        in both the shadow and (when given) the real BlockManager."""
        bm = bm if bm is not None else self._bm
        for rid, blocks in self.owner.items():
            self._find("refcount-leak",
                       f"request {rid} still holds blocks {blocks} at "
                       f"quiescence")
        for b in range(self.n_blocks):
            if self.ref[b] != 0:
                self._find("refcount-leak",
                           f"block {b} has shadow refcount {self.ref[b]} "
                           f"at quiescence")
            if bm is not None and bm.refcount(b) != self.ref[b]:
                self._find("refcount-drift",
                           f"block {b} refcount {bm.refcount(b)} != shadow "
                           f"{self.ref[b]} at quiescence")
        return self.findings
