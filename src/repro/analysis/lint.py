"""Repo-specific AST lint (stdlib-only; no new dependencies).

Rules encode the invariants this codebase keeps re-fixing by hand:

  * ``kv-bytes-formula``  — KV byte arithmetic (the ``2 * n_kv_heads *
    head_dim * itemsize`` pattern) must route through
    ``models.attention.paged_kv_token_bytes`` /
    ``roofline.analytic.kv_token_bytes``; re-derived formulas drift the
    moment the layout changes (int8 scale/zero leaves did exactly
    that). Blessed definition sites: ``models/attention.py``,
    ``roofline/analytic.py``, ``core/types.py``.
  * ``private-blockmanager`` — no access to ``BlockManager`` private
    state (``_ref``, ``_index``, ``_hash_of``, ``_cached``, ``_free``,
    ``_take_block``, …) outside ``serving/kvcache.py``; everything else
    goes through the public API (``refcount``, ``free_blocks``,
    ``indexed_hashes``, hooks).
  * ``wallclock-in-sim``  — no wall-clock (``time.time`` & friends,
    ``datetime.now``) or global-RNG (``random.*``, ``np.random.*``)
    calls in the simulation/fleet modules (``fleet/``, ``cluster/``,
    ``serving/simulation.py``): those layers take an injected clock /
    seeded generator so runs replay deterministically.
  * ``runtime-assert``    — no bare ``assert`` guarding runtime
    invariants in the KV-lifecycle modules (``serving/kvcache.py``,
    ``runner.py``, ``worker.py``, ``engine.py``, ``migration.py``,
    ``scheduler.py``, ``router/kvtier.py``, ``store/kvsegment.py``):
    ``python -O`` strips asserts, so invariant guards raise
    ``KVInvariantError`` / ``ValueError`` explicitly.
  * ``blanket-except``    — no ``except Exception`` (or bare
    ``except:``) whose handler neither re-raises nor records the error
    (logging / traceback / print / structured error capture).
  * ``jit-static-shape``  — ``jax.jit`` entry points must take bucketed
    shapes: ``static_argnums``/``static_argnames`` turn every distinct
    value into a fresh executable, so each use needs an explicit waiver
    acknowledging the bound on the cache.

Suppress a finding with a same-line comment::

    something_flagged()   # repro-lint: allow[rule-name]

The checked-in baseline (``lint_baseline.json``, per-file per-rule
counts) ratchets: runs fail on findings above the baseline and report
when the baseline itself can be tightened. The repo's baseline is
empty — the tree lints clean.

Run: ``python -m repro.analysis.lint`` (or the ``repro-lint`` console
script).
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import re
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

__all__ = ["LintFinding", "lint_file", "lint_tree", "main"]

_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\[([a-z0-9_,\- ]+)\]")

# rule scopes, as path suffixes relative to the package root
KV_BYTES_BLESSED = ("models/attention.py", "roofline/analytic.py",
                    "core/types.py")
BLOCKMGR_HOME = ("serving/kvcache.py",)
BLOCKMGR_PRIVATE = frozenset({
    "_ref", "_index", "_hash_of", "_cached", "_free", "_take_block",
    "_ref_block", "_unref_block", "_fire_commit", "_fire_evict",
    "_n_hashed", "_chain",
})
SIM_SCOPE = ("fleet/", "cluster/", "serving/simulation.py")
RUNTIME_ASSERT_SCOPE = (
    "serving/kvcache.py", "serving/runner.py", "serving/worker.py",
    "serving/engine.py", "serving/migration.py", "serving/scheduler.py",
    "router/kvtier.py", "store/kvsegment.py",
)
WALLCLOCK_CALLS = {
    ("time", "time"), ("time", "monotonic"), ("time", "perf_counter"),
    ("time", "time_ns"), ("time", "monotonic_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("date", "today"),
}
# global-RNG factories that are fine: they *construct* seeded generators
RNG_ALLOWED = {"default_rng", "Generator", "PRNGKey", "Random", "seed"}


@dataclass(frozen=True)
class LintFinding:
    path: str       # repo-relative
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _suffix_match(relpath: str, suffixes) -> bool:
    rp = relpath.replace(os.sep, "/")
    return any(rp.endswith(s) or f"/{s}" in rp or rp.startswith(s)
               for s in suffixes)


def _allowed_rules(source_lines: List[str], lineno: int) -> frozenset:
    """Rules waived by a ``# repro-lint: allow[...]`` comment on the
    finding's line (or the line above, for wrapped statements)."""
    out = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(source_lines):
            m = _ALLOW_RE.search(source_lines[ln - 1])
            if m:
                out.update(p.strip() for p in m.group(1).split(","))
    return frozenset(out)


def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """('np', 'random', 'rand') for ``np.random.rand`` — None if the
    chain has non-name parts."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


class _Checker(ast.NodeVisitor):
    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.lines = source.splitlines()
        self.findings: List[LintFinding] = []
        self.in_sim = _suffix_match(relpath, SIM_SCOPE)
        self.kv_blessed = _suffix_match(relpath, KV_BYTES_BLESSED)
        self.bm_home = _suffix_match(relpath, BLOCKMGR_HOME)
        self.assert_scope = _suffix_match(relpath, RUNTIME_ASSERT_SCOPE)
        self._kv_seen: set = set()   # inner Mult nodes already reported

    def _emit(self, node: ast.AST, rule: str, message: str):
        line = getattr(node, "lineno", 1)
        if rule in _allowed_rules(self.lines, line):
            return
        self.findings.append(LintFinding(self.relpath, line, rule, message))

    # ---------------------------------------------------- kv-bytes-formula
    def _mult_names(self, node: ast.AST, names: set):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
            self._mult_names(node.left, names)
            self._mult_names(node.right, names)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
        elif isinstance(node, ast.Name):
            names.add(node.id)

    def visit_BinOp(self, node: ast.BinOp):
        if (isinstance(node.op, ast.Mult) and not self.kv_blessed
                and id(node) not in self._kv_seen):
            names: set = set()
            self._mult_names(node, names)
            if "n_kv_heads" in names and "head_dim" in names:
                self._emit(node, "kv-bytes-formula",
                           "KV bytes re-derived from n_kv_heads*head_dim: "
                           "route through attention.paged_kv_token_bytes / "
                           "analytic.kv_token_bytes (int8 pools carry "
                           "scale/zero bytes this formula misses)")
                # one finding per multiply chain, not per inner node
                for sub in ast.walk(node):
                    if isinstance(sub, ast.BinOp):
                        self._kv_seen.add(id(sub))
        self.generic_visit(node)

    # ------------------------------------------------ private-blockmanager
    def visit_Attribute(self, node: ast.Attribute):
        if not self.bm_home and node.attr in BLOCKMGR_PRIVATE:
            base = _dotted(node.value)
            # self._free etc. on *other* classes is fine unless the base
            # looks like a block manager handle
            if base is not None and (
                    base[-1] in ("block_mgr", "bm", "block_manager",
                                 "blockmgr")
                    or (len(base) > 1 and base[-1] in BLOCKMGR_PRIVATE)):
                self._emit(node, "private-blockmanager",
                           f"access to BlockManager private state "
                           f"'.{node.attr}' outside serving/kvcache.py — "
                           f"use the public API (refcount, free_blocks, "
                           f"indexed_hashes, hooks)")
        self.generic_visit(node)

    # --------------------------------------------------- wallclock-in-sim
    def visit_Call(self, node: ast.Call):
        d = _dotted(node.func)
        if self.in_sim and d is not None:
            if (d[-2:] in WALLCLOCK_CALLS
                    or (len(d) >= 2 and d[-2] == "random"
                        and d[-1] not in RNG_ALLOWED)
                    or (d[0] == "random" and len(d) == 2
                        and d[-1] not in RNG_ALLOWED)):
                self._emit(node, "wallclock-in-sim",
                           f"'{'.'.join(d)}' in a simulation/fleet module: "
                           f"inject the clock / a seeded generator so runs "
                           f"replay deterministically")
        if d is not None and d[-1] == "jit" and len(d) >= 2 \
                and d[-2] in ("jax",):
            for kw in node.keywords:
                if kw.arg in ("static_argnums", "static_argnames"):
                    self._emit(node, "jit-static-shape",
                               f"jax.jit({kw.arg}=…) compiles one "
                               f"executable per distinct value — bucket "
                               f"the shape instead, or waive with "
                               f"'# repro-lint: allow[jit-static-shape]' "
                               f"stating the bound")
        self.generic_visit(node)

    # ------------------------------------------------------ runtime-assert
    def visit_Assert(self, node: ast.Assert):
        if self.assert_scope:
            self._emit(node, "runtime-assert",
                       "bare assert guards a runtime invariant here but "
                       "python -O strips it — raise KVInvariantError / "
                       "ValueError explicitly")
        self.generic_visit(node)

    # ------------------------------------------------------ blanket-except
    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        blanket = node.type is None or (
            isinstance(node.type, ast.Name)
            and node.type.id in ("Exception", "BaseException"))
        if blanket and not self._handler_accounts(node):
            self._emit(node, "blanket-except",
                       "blanket 'except Exception' that neither re-raises "
                       "nor records the error — narrow the types or log / "
                       "re-raise")
        self.generic_visit(node)

    @staticmethod
    def _handler_accounts(node: ast.ExceptHandler) -> bool:
        """Handler re-raises, logs, prints, or captures the error."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Raise):
                return True
            if isinstance(sub, ast.Call):
                d = _dotted(sub.func)
                if d is None:
                    continue
                if d[-1] in ("print", "print_exc", "exception", "warning",
                             "warn", "error", "critical", "format_exc",
                             "log"):
                    return True
            # `rec = {... "error": str(e)}`-style capture
            if isinstance(sub, ast.Dict):
                for k in sub.keys:
                    if isinstance(k, ast.Constant) and k.value in (
                            "error", "exception", "err"):
                        return True
        return False


def lint_file(path: str, relpath: Optional[str] = None) -> List[LintFinding]:
    relpath = relpath or path
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintFinding(relpath, e.lineno or 1, "syntax-error", str(e))]
    checker = _Checker(relpath, source)
    checker.visit(tree)
    return checker.findings


def lint_tree(root: str) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", ".git"))
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            findings.extend(lint_file(full, os.path.relpath(full,
                                                            root)))
    return findings


# ------------------------------------------------------------- baseline
def _counts(findings: List[LintFinding]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in findings:
        key = f"{f.path.replace(os.sep, '/')}::{f.rule}"
        out[key] = out.get(key, 0) + 1
    return out


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "lint_baseline.json")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="Repo-specific AST lint with a ratcheting baseline.")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint (default: the "
                         "repro package root)")
    ap.add_argument("--baseline", default=default_baseline_path())
    ap.add_argument("--write-baseline", action="store_true",
                    help="freeze the current findings as the new baseline")
    args = ap.parse_args(argv)

    roots = args.paths or [os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))]       # src/repro
    findings: List[LintFinding] = []
    for r in roots:
        if os.path.isdir(r):
            findings.extend(lint_tree(r))
        else:
            findings.extend(lint_file(r, os.path.basename(r)))

    if args.write_baseline:
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(_counts(findings), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"repro-lint: baseline frozen with {len(findings)} "
              f"finding(s) -> {args.baseline}")
        return 0

    baseline: Dict[str, int] = {}
    if os.path.exists(args.baseline):
        with open(args.baseline, encoding="utf-8") as f:
            baseline = json.load(f)

    counts = _counts(findings)
    new = {k: c - baseline.get(k, 0) for k, c in counts.items()
           if c > baseline.get(k, 0)}
    fixed = {k: baseline[k] - counts.get(k, 0) for k in baseline
             if counts.get(k, 0) < baseline[k]}

    if new:
        allowed = dict(baseline)
        for f in findings:
            key = f"{f.path.replace(os.sep, '/')}::{f.rule}"
            if allowed.get(key, 0) > 0:
                allowed[key] -= 1          # covered by the baseline
                continue
            print(str(f))
        print(f"repro-lint: {sum(new.values())} new finding(s) above the "
              f"baseline")
        return 1
    if fixed:
        print(f"repro-lint: clean; baseline can ratchet down "
              f"({sum(fixed.values())} stale allowance(s): "
              f"{', '.join(sorted(fixed))}) — rerun with --write-baseline")
    else:
        print(f"repro-lint: clean ({len(findings)} baselined finding(s))"
              if findings else "repro-lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
