"""Correctness tooling: KV-lifecycle sanitizer, repo lint, Pallas checks.

Three coordinated checkers over the serving stack's most fragile shared
contract — the paged-KV block lifecycle — plus the repo-specific static
rules we keep re-fixing by hand:

  * ``sanitizer``   — a shadow BlockManager mirroring every
    allocate/extend/commit/free/evict/spill/restore/migrate event
    (``Engine(sanitize=True)`` / ``REPRO_SANITIZE=1``);
  * ``lint``        — AST-based repo lint (``python -m repro.analysis.lint``)
    with a frozen, ratcheting baseline;
  * ``kernelcheck`` — static pre-launch validation of the Pallas kernel
    calling conventions (grid/BlockSpec consistency, 8/128 tile
    alignment, scalar-prefetch shapes, the pad-row convention), run from
    ``kernels/ops.py`` dispatch in sanitize mode.

Nothing here sits on a hot path unless explicitly enabled: every
instrumentation point in serving/ is a ``if self.tracer is not None``
guard around an attribute that defaults to ``None``.
"""
