"""Static pre-launch checks for the Pallas attention kernels.

Validates the calling conventions of ``kernels/ragged_attention.py`` and
``kernels/decode_attention.py`` *before* a launch is traced — rank and
shape consistency between the operands that become the grid /
BlockSpecs / scalar-prefetch arguments, the 8-sublane / 128-lane tile
alignment the TPU layouts require, int8 quant-leaf shapes, and the
pad-row convention (``pos = -1`` tokens are masked and their writes
routed to the trash page, so the position operand must be a *signed*
integer type).

Called from ``kernels/ops.py`` dispatch when sanitize mode is on
(``REPRO_SANITIZE=1`` / ``ops.set_sanitize_mode(True)``). Because the
dispatch wrappers execute at jit-trace time, a check runs once per
compiled shape, not once per step — and on concrete (untraced) inputs it
additionally validates the *values*: page ids inside the pool, row ids
inside the batch, positions ≥ -1.

Violations raise :class:`KernelContractError`. Alignment problems that
only matter on real TPU tiles (head_dim % 128, page_size % 8) are
errors under the ``pallas`` backend and warnings under
``interpret``/``ref``, where CPU smoke shapes are legitimately tiny.
"""

from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.models.attention import KV_QUANT_LEAVES

__all__ = ["KernelContractError", "check_ragged_paged",
           "check_paged_decode"]

LANE = 128     # TPU lane width: last dim of a tile
SUBLANE = 8    # TPU sublane width: second-to-last dim of a tile


class KernelContractError(ValueError):
    """A kernel operand violates the launch contract."""


def _shape(x):
    return tuple(x.shape)


def _is_concrete(x) -> bool:
    """True when the operand carries real values (not a jit tracer)."""
    try:
        import jax
        return not isinstance(x, jax.core.Tracer)
    except ImportError:                      # pragma: no cover
        return True


def _err(msg: str):
    raise KernelContractError(msg)


def _align(what: str, value: int, mult: int, backend: str):
    """8/128 tile alignment: hard error on the compiled pallas backend,
    warning elsewhere (interpret/ref run un-tiled)."""
    if value % mult == 0:
        return
    msg = (f"{what} = {value} is not a multiple of {mult}: the TPU tile "
           f"layout would pad or miscompile this launch")
    if backend == "pallas":
        _err(msg)
    warnings.warn(f"kernelcheck: {msg} (backend={backend!r}: tolerated)",
                  stacklevel=3)


def _check_pages(k_pages, v_pages, backend: str):
    if k_pages.ndim != 4:
        _err(f"k_pages must be (n_pages, page_size, n_kv_heads, head_dim), "
             f"got {_shape(k_pages)}")
    if _shape(k_pages) != _shape(v_pages):
        _err(f"k_pages {_shape(k_pages)} != v_pages {_shape(v_pages)}")
    if k_pages.dtype != v_pages.dtype:
        _err(f"k_pages dtype {k_pages.dtype} != v_pages dtype "
             f"{v_pages.dtype}")
    n_pages, page_size, _hkv, hd = k_pages.shape
    if n_pages < 2:
        _err(f"n_pages = {n_pages}: the pool must hold at least one real "
             f"page plus the trailing null/trash page (n_blocks + 1)")
    _align("page_size", page_size, SUBLANE, backend)
    _align("head_dim", hd, LANE, backend)


def _check_quant(kv_quant, k_pages):
    if kv_quant is None:
        return
    missing = [l for l in KV_QUANT_LEAVES if l not in kv_quant]
    if missing:
        _err(f"kv_quant missing leaves {missing}: int8 pools carry "
             f"{KV_QUANT_LEAVES}")
    want = _shape(k_pages)[:-1]
    for leaf in KV_QUANT_LEAVES:
        a = kv_quant[leaf]
        if _shape(a) != want:
            _err(f"kv_quant[{leaf!r}] shape {_shape(a)} != k_pages[:-1] "
                 f"{want}")
        if np.dtype(a.dtype) != np.dtype(np.float32):
            _err(f"kv_quant[{leaf!r}] dtype {a.dtype}: scale/zero leaves "
                 f"are float32")


def check_ragged_paged(q, k_pages, v_pages, tables, row, pos, *,
                       kv_quant=None, tile_q: int = 8,
                       backend: str = "ref"):
    """Contract of ``ragged_attention.ragged_paged_attention``: q (T,
    Hq, hd) flattened tokens, T tile_q-aligned; ``row``/``pos`` (T,) the
    per-token scalar-prefetch descriptors (row constant per tile, pos =
    -1 marks pads); ``tables`` (B, nb) the second scalar-prefetch
    operand; grid = (T/tile_q, Hkv, nb)."""
    if q.ndim != 3:
        _err(f"q must be (T, n_q_heads, head_dim), got {_shape(q)}")
    t, hq, hd = q.shape
    _check_pages(k_pages, v_pages, backend)
    n_pages, page_size, hkv, hd_kv = k_pages.shape
    if hd_kv != hd:
        _err(f"q head_dim {hd} != page head_dim {hd_kv}")
    if hq % hkv != 0:
        _err(f"n_q_heads {hq} not a multiple of n_kv_heads {hkv} (GQA "
             f"grouping)")
    if tile_q % SUBLANE != 0:
        _err(f"tile_q = {tile_q} must be a multiple of {SUBLANE} "
             f"(sublane tiling)")
    if t % tile_q != 0:
        _err(f"T = {t} tokens not a multiple of tile_q = {tile_q}: the "
             f"caller pads each segment's span to tile alignment")
    if tables.ndim != 2:
        _err(f"tables must be (B, nb), got {_shape(tables)}")
    for name, a in (("row", row), ("pos", pos)):
        if a.ndim != 1 or a.shape[0] != t:
            _err(f"{name} must be ({t},) to match the flattened token "
                 f"axis, got {_shape(a)}")
        if not np.issubdtype(np.dtype(a.dtype), np.integer):
            _err(f"{name} dtype {a.dtype}: scalar-prefetch descriptors "
                 f"are integer")
    if not np.issubdtype(np.dtype(pos.dtype), np.signedinteger):
        _err(f"pos dtype {pos.dtype} cannot carry the pad marker -1 "
             f"(pad rows → zeros convention needs a signed type)")
    _check_quant(kv_quant, k_pages)
    if _is_concrete(tables) and _is_concrete(row) and _is_concrete(pos):
        tb = np.asarray(tables)
        if tb.min() < 0 or tb.max() >= n_pages:
            _err(f"tables reference page ids outside [0, {n_pages}): "
                 f"range [{tb.min()}, {tb.max()}]")
        rw = np.asarray(row)
        if rw.min() < 0 or rw.max() >= tables.shape[0]:
            _err(f"row references table rows outside "
                 f"[0, {tables.shape[0]}): range [{rw.min()}, {rw.max()}]")
        ps = np.asarray(pos)
        if ps.min() < -1:
            _err(f"pos carries values below the pad marker -1 "
                 f"(min {ps.min()})")
        # row must be constant within each tile_q tile (kernel layout
        # contract: one table row per query tile)
        tiles = rw.reshape(-1, tile_q)
        if not (tiles == tiles[:, :1]).all():
            bad = int(np.argmax((tiles != tiles[:, :1]).any(axis=1)))
            _err(f"row changes inside query tile {bad}: segments must be "
                 f"padded so each tile_q span stays on one table row")


def check_paged_decode(q, k_pages, v_pages, block_tables, kv_len, *,
                       backend: str = "ref"):
    """Contract of ``decode_attention.paged_decode_attention``: q (B, 1,
    Hq, hd) one token per sequence; ``block_tables`` (B, nb) page ids;
    ``kv_len`` (B,) valid rows per sequence."""
    if q.ndim != 4 or q.shape[1] != 1:
        _err(f"q must be (B, 1, n_q_heads, head_dim), got {_shape(q)}")
    b, _one, hq, hd = q.shape
    _check_pages(k_pages, v_pages, backend)
    n_pages, page_size, hkv, hd_kv = k_pages.shape
    if hd_kv != hd:
        _err(f"q head_dim {hd} != page head_dim {hd_kv}")
    if hq % hkv != 0:
        _err(f"n_q_heads {hq} not a multiple of n_kv_heads {hkv} (GQA "
             f"grouping)")
    if block_tables.ndim != 2 or block_tables.shape[0] != b:
        _err(f"block_tables must be ({b}, nb), got {_shape(block_tables)}")
    if kv_len.ndim != 1 or kv_len.shape[0] != b:
        _err(f"kv_len must be ({b},), got {_shape(kv_len)}")
    if not np.issubdtype(np.dtype(block_tables.dtype), np.integer):
        _err(f"block_tables dtype {block_tables.dtype}: page ids are "
             f"integer")
    if _is_concrete(block_tables) and _is_concrete(kv_len):
        tb = np.asarray(block_tables)
        if tb.min() < 0 or tb.max() >= n_pages:
            _err(f"block_tables reference page ids outside [0, {n_pages}): "
                 f"range [{tb.min()}, {tb.max()}]")
        kl = np.asarray(kv_len)
        if kl.min() < 0 or kl.max() > block_tables.shape[1] * page_size:
            _err(f"kv_len range [{kl.min()}, {kl.max()}] exceeds the "
                 f"table capacity {block_tables.shape[1]} blocks × "
                 f"{page_size} rows")
