"""Roofline terms from a compiled dry-run artifact.

  compute term    = FLOPs_total          / (chips * 197 TFLOP/s bf16)
  memory term     = HBM_bytes_per_device / 819 GB/s
  collective term = ICI_bytes_per_device / 50 GB/s per link

Primary sources:
  * FLOPs / HBM bytes: the analytic model in roofline/analytic.py.
    (XLA:CPU ``cost_analysis()`` does not multiply while-loop bodies by
    trip count — verified to under-report a scan-over-40-layers prefill by
    exactly 40x — so its numbers are recorded as ``xla_*`` but not used.)
  * collective bytes: loop-aware parse of the partitioned HLO text
    (``compiled.as_text()``): collective ops' local result-shape bytes,
    multiplied by the trip counts of enclosing while loops.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.roofline import analytic

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    entry: Optional[str] = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry is None and comps:
        entry = list(comps)[-1]
    comps["__entry__"] = [entry]          # type: ignore[list-item]
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Loop-aware per-device collective bytes by kind."""
    comps = _split_computations(hlo_text)
    entry = comps.pop("__entry__")[0]

    info = {}
    for name, lines in comps.items():
        colls, whiles, calls, consts = [], [], [], [0]
        for line in lines:
            if "-done(" in line:
                continue
            m = _OP_RE.search(line)
            if m:
                colls.append((m.group(2), _shape_bytes(m.group(1))))
            w = _WHILE_RE.search(line)
            if w:
                whiles.append((w.group(1), w.group(2)))
            c = _CALL_RE.search(line)
            if c:
                calls.append(c.group(1))
            for k in _CONST_RE.findall(line):
                consts.append(int(k))
        info[name] = (colls, whiles, calls, max(consts))

    mult = {name: 0.0 for name in info}
    if entry in mult:
        mult[entry] = 1.0
    # propagate multipliers to fixpoint (HLO computation graph is acyclic)
    for _ in range(len(info)):
        changed = False
        new = dict(mult)
        for name, (colls, whiles, calls, _) in info.items():
            m = mult.get(name, 0.0)
            if m <= 0:
                continue
            for cond, body in whiles:
                trip = info.get(cond, ([], [], [], 1))[3] or 1
                want = m * max(trip, 1)
                if new.get(body, 0.0) < want:
                    new[body] = want
                    changed = True
            for callee in calls:
                if new.get(callee, 0.0) < m:
                    new[callee] = m
                    changed = True
        mult = new
        if not changed:
            break

    out = {k: 0 for k in _COLLECTIVES}
    for name, (colls, _, _, _) in info.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for kind, nbytes in colls:
            out[kind] += int(nbytes * m)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_total: float              # analytic, whole step
    bytes_per_device: float         # analytic HBM traffic
    coll_bytes_per_device: Dict[str, int]   # parsed from HLO
    peak_memory_per_device: float
    model_flops_total: float
    xla_flops_per_device: float = 0.0
    xla_bytes_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_total / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes_per_device.values()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return (self.model_flops_total / self.flops_total
                if self.flops_total else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """useful FLOPs / (chips*peak*dominant-term-time): the score."""
        denom = self.chips * PEAK_FLOPS * self.bound_s
        return self.model_flops_total / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops_total,
            "flops_total": self.flops_total,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_gb": self.peak_memory_per_device / (1 << 30),
            "coll_bytes": dict(self.coll_bytes_per_device),
            "xla_flops_dev": self.xla_flops_per_device,
            "xla_bytes_dev": self.xla_bytes_per_device,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D prefill, 2*N*B decode;
    N = active params for MoE."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def analyze(arch: str, shape, mesh_name: str, chips: int, cost: dict,
            memory_stats, hlo_text: str, cfg,
            policy: str = "baseline", kv_dtype=None) -> Roofline:
    """``kv_dtype`` parameterizes the analytic KV-traffic term on the KV
    pool storage dtype (serving engines with quantized pages); ``None``
    keeps the legacy bf16 assumption."""
    train_mult = 4.0 if shape.kind == "train" else 1.0  # fwd+remat+bwd
    flops = analytic.step_flops(cfg, shape,
                                causal_skip="skip" in policy) * train_mult
    pbytes = cfg.size_bytes()
    hbm = analytic.hbm_bytes_per_device(cfg, shape, chips, pbytes,
                                        train_mult, kv_dtype=kv_dtype)
    coll = collective_bytes(hlo_text)
    peak_mem = getattr(memory_stats, "temp_size_in_bytes", 0) + \
        getattr(memory_stats, "argument_size_in_bytes", 0)
    return Roofline(
        arch, shape.name, mesh_name, chips, flops, hbm, coll, peak_mem,
        model_flops(cfg, shape),
        xla_flops_per_device=float(cost.get("flops", 0.0)),
        xla_bytes_per_device=float(cost.get("bytes accessed", 0.0)))
