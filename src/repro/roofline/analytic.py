"""First-principles roofline terms per (arch x shape x mesh).

XLA:CPU ``cost_analysis()`` does not multiply while-loop bodies by their
trip count (verified: granite prefill under-reports FLOPs by exactly
n_layers), so the scan-over-layers models make its numbers useless for a
roofline. These analytic terms model what the implementation actually
executes (masked-full attention, capacity-MoE dispatch, remat recompute)
and are the primary numbers in EXPERIMENTS.md §Roofline; the raw XLA
numbers and the loop-aware HLO collective parse are recorded alongside.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2


def _attn_flops(cfg: ModelConfig, tokens: float, ctx: float,
                causal_skip: bool = False) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * tokens * d * (hq * hd) + 2 * 2 * tokens * d * (hkv * hd) \
        + 2 * tokens * (hq * hd) * d
    if causal_skip and tokens > ctx / 2:
        # q block i scans ceil((i+1)*qb/kb) kv blocks: factor (nq+1)/(2nq)
        nq = max(int(ctx) // 2048, 1)
        ctx = ctx * (nq + 1) / (2 * nq)
    attn = 2 * 2 * tokens * ctx * hq * hd
    return proj + attn


def _dense_mlp_flops(cfg: ModelConfig, tokens: float, ff: int) -> float:
    return 3 * 2 * tokens * cfg.d_model * ff


def _moe_flops(cfg: ModelConfig, tokens: float) -> float:
    d, eff = cfg.d_model, cfg.expert_d_ff
    routed_rows = tokens * cfg.top_k * cfg.capacity_factor
    f = 3 * 2 * routed_rows * d * eff
    f += 2 * tokens * d * cfg.n_experts                  # router
    if cfg.n_shared_experts:
        f += 3 * 2 * tokens * d * (cfg.n_shared_experts * eff)
    return f


def _mamba_flops(cfg: ModelConfig, tokens: float) -> float:
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    r = max(1, d_in // 16)
    f = 2 * tokens * d * 2 * d_in                        # in_proj
    f += 2 * tokens * cfg.mamba_d_conv * d_in            # conv
    f += 2 * tokens * d_in * (r + 2 * n)                 # x_proj
    f += 2 * tokens * r * d_in                           # dt_proj
    f += 8 * tokens * d_in * n                           # selective scan
    f += 2 * tokens * d_in * d                           # out_proj
    return f


def _rwkv_flops(cfg: ModelConfig, tokens: float) -> float:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    f = 5 * 2 * tokens * d * d                           # r/k/v/g/o ... w_o
    f += 2 * 2 * tokens * d * 64                         # decay lora
    f += 4 * tokens * h * hd * hd                        # wkv recurrence
    return f


def step_flops(cfg: ModelConfig, shape: ShapeConfig,
               causal_skip: bool = False) -> float:
    """Forward FLOPs of one step (train multiplier applied by caller)."""
    if shape.kind == "decode":
        tokens = float(shape.global_batch)
        ctx = float(shape.seq_len)
    else:
        seq = shape.seq_len
        if cfg.family == "vlm":
            seq = shape.seq_len  # image prefix included in assigned seq
        tokens = float(shape.global_batch * seq)
        ctx = float(seq)

    total = 0.0
    for mix, mlp in cfg.layer_plan:
        if mix == "attn":
            total += _attn_flops(cfg, tokens, ctx, causal_skip)
        elif mix == "mamba":
            total += _mamba_flops(cfg, tokens)
        else:
            total += _rwkv_flops(cfg, tokens)
        if mlp == "dense":
            total += _dense_mlp_flops(cfg, tokens, cfg.d_ff)
        elif mlp == "moe":
            total += _moe_flops(cfg, tokens)

    if cfg.is_encdec:
        enc_tokens = shape.global_batch * cfg.n_audio_frames
        if shape.kind == "decode":
            # cross-attn reads the precomputed encoder KV
            total += 2 * 2 * tokens * cfg.n_audio_frames * \
                cfg.n_heads * cfg.head_dim * cfg.n_layers
            total += 2 * tokens * cfg.d_model * (cfg.n_heads * cfg.head_dim
                                                 ) * 2 * cfg.n_layers
        else:
            for _ in range(cfg.encoder_layers):
                total += _attn_flops(cfg, enc_tokens, cfg.n_audio_frames)
                total += _dense_mlp_flops(cfg, enc_tokens, cfg.d_ff)
            for _ in range(cfg.n_layers):     # cross attention in decoder
                total += _attn_flops(cfg, tokens, cfg.n_audio_frames)

    # head
    head_tokens = tokens if shape.kind == "train" else float(
        shape.global_batch)
    total += 2 * head_tokens * cfg.d_model * cfg.padded_vocab
    return total


def kv_token_bytes(cfg: ModelConfig, kv_dtype=None) -> int:
    """Exact KV bytes one token occupies across ALL attention layers —
    the per-period figure (storage dtype + quant scale/zero overhead)
    delegated to the serving layer's single source of truth
    (attention.paged_kv_token_bytes) times the attention layer count.
    ``kv_dtype=None`` means bf16-class storage (the legacy roofline
    assumption: 2 bytes/element, no overhead)."""
    n_attn = sum(1 for m, _ in cfg.layer_plan if m == "attn")
    if kv_dtype is None:
        return 2 * cfg.n_kv_heads * cfg.head_dim * BF16 * n_attn
    from repro.models.attention import paged_kv_token_bytes
    return paged_kv_token_bytes(cfg, kv_dtype) * n_attn


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                         chips: int, param_bytes_total: int,
                         train_mult: float, kv_dtype=None) -> float:
    """First-order HBM traffic per device per step. ``kv_dtype``
    parameterizes the KV-stream term on the pool storage dtype (int8
    pages roughly halve decode's KV traffic at production head_dim);
    ``None`` keeps the legacy bf16 formula exactly."""
    d = cfg.d_model
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    # weights stream: TP shards weights across 'model' (and 'data' if fsdp);
    # every device reads its shard each pass
    w_dev = param_bytes_total / (chips if cfg.fsdp else 16)
    passes = 3.0 if shape.kind == "train" else 1.0   # fwd + recompute + bwd
    traffic = w_dev * passes
    if shape.kind == "train":
        # optimizer: read mu,nu,params + write all three (fp32 states)
        opt_dev = 2 * param_bytes_total * 2 / chips    # fp32 mu+nu sharded
        traffic += 2 * opt_dev + 2 * w_dev
    # activations: residual stream r/w per layer
    act = cfg.n_layers * (tokens / chips if shape.kind != "decode"
                          else tokens / min(chips, 16)) * d * BF16 * 4
    traffic += act * (2 if shape.kind == "train" else 1)
    # KV cache
    kv_tok = kv_token_bytes(cfg, kv_dtype)
    if shape.kind == "decode":
        traffic += kv_tok * shape.seq_len * shape.global_batch / chips
        # recurrent states
        if cfg.sub_quadratic:
            d_in = cfg.mamba_expand * d
            n_m = sum(1 for m, _ in cfg.layer_plan if m == "mamba")
            n_r = sum(1 for m, _ in cfg.layer_plan if m == "rwkv")
            traffic += (n_m * d_in * cfg.mamba_d_state * 4
                        + n_r * cfg.n_heads * cfg.head_dim ** 2 * 4) \
                * 2 * shape.global_batch / min(chips, 16)
    elif shape.kind == "prefill":
        traffic += kv_tok * tokens / chips
    return traffic


@dataclass
class CollectiveModel:
    """Per-device ICI bytes per step under the baseline layout."""
    allreduce: float = 0.0
    allgather: float = 0.0
    reducescatter: float = 0.0
    alltoall: float = 0.0

    @property
    def total(self) -> float:
        return (self.allreduce + self.allgather + self.reducescatter
                + self.alltoall)


def collective_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                                chips: int, param_bytes_total: int,
                                data: int = 16, model: int = 16) -> \
        CollectiveModel:
    cm = CollectiveModel()
    ring = 2.0                      # ~2(n-1)/n per all-reduce
    if shape.kind == "decode":
        tok_local = shape.global_batch / (data if shape.global_batch > 1
                                          else 1)
    else:
        tok_local = shape.global_batch * shape.seq_len / data
    act = tok_local * cfg.d_model * BF16
    # TP: one all-reduce (or RS+AG) per mixer and per mlp per layer
    per_layer = 2 * act * ring
    passes = 3.0 if shape.kind == "train" else 1.0
    cm.allreduce += per_layer * cfg.n_layers * passes
    if cfg.is_encdec and shape.kind != "decode":
        enc_local = shape.global_batch * cfg.n_audio_frames / data
        cm.allreduce += 3 * enc_local * cfg.d_model * BF16 * ring \
            * cfg.encoder_layers * passes
    # MoE all-to-all: dispatch + combine of routed rows
    if cfg.is_moe and cfg.expert_sharding == "expert":
        moe_layers = sum(1 for _, m in cfg.layer_plan if m == "moe")
        rows = tok_local * cfg.top_k * cfg.capacity_factor
        cm.alltoall += 2 * rows * cfg.d_model * BF16 * moe_layers * passes
    # FSDP: all-gather weights every pass + reduce-scatter grads
    if cfg.fsdp:
        w_dev = param_bytes_total / chips
        cm.allgather += w_dev * (data - 1) / data * passes * data / data
        cm.allgather += param_bytes_total / model / data * passes
    if shape.kind == "train":
        # DP gradient reduction (bf16 compressed)
        grad_dev = param_bytes_total / (chips if cfg.fsdp else model)
        cm.reducescatter += grad_dev * ring
    return cm
