"""Application presets from the paper (Table 1 + Table 2).

SLOs derive from warm-request latencies: global TTFT SLO = 5x warm TTFT,
TPOT SLO = 2x warm TPOT; summarization TTFT doubled; chatbot TPOT aligned to
300 wpm reading speed (= 200 ms/token).
Prompt/output length statistics approximate ShareGPT / HumanEval / LongBench.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.types import GB, SLO, ModelProfile, TimingProfile


@dataclass(frozen=True)
class WarmProfile:
    model: str
    size_bytes: int
    gpu: str
    ttft: float      # Table 1
    tpot: float


WARM = {
    "llama2-7b": WarmProfile("llama2-7b", int(12.5 * GB), "A10", 1.5, 0.042),
    "llama2-13b": WarmProfile("llama2-13b", int(24.2 * GB), "V100", 2.4, 0.058),
    "opt-6.7b": WarmProfile("opt-6.7b", int(13.3 * GB), "A10", 1.4, 0.040),
}


@dataclass(frozen=True)
class Application:
    name: str
    model: str
    slo: SLO
    mean_prompt: int
    mean_output: int
    dataset: str


# Table 2 — note the paper's per-app SLO adjustments.
APPLICATIONS = [
    Application("chatbot-7b", "llama2-7b", SLO(7.5, 0.200), 315, 240,
                "ShareGPT"),
    Application("chatbot-13b", "llama2-13b", SLO(12.0, 0.200), 315, 240,
                "ShareGPT"),
    Application("code-7b", "llama2-7b", SLO(7.5, 0.084), 150, 60,
                "HumanEval"),
    Application("code-13b", "llama2-13b", SLO(12.0, 0.116), 150, 60,
                "HumanEval"),
    Application("summ-7b", "llama2-7b", SLO(15.0, 0.084), 3000, 200,
                "LongBench"),
    Application("summ-13b", "llama2-13b", SLO(24.0, 0.116), 3000, 200,
                "LongBench"),
]


def timings_for(model: str) -> TimingProfile:
    w = WARM[model]
    return TimingProfile(t_p=w.ttft, t_d=w.tpot)


def kv_bytes_for(model: str) -> int:
    """Per-token KV footprint from the registered model geometry (fp16):
    for llama2-7b this reproduces the 512 KiB/token constant the
    simulation used to hardcode; 13B-class models pin ~1.6x that."""
    from repro.configs import get_config       # paper_models registers these
    cfg = get_config(model)
    n_attn = cfg.n_periods * sum(1 for m in cfg.mixer_pattern if m == "attn")
    return ModelProfile.kv_bytes_from_geometry(n_attn, cfg.n_kv_heads,
                                               cfg.head_dim)
