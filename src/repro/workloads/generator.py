"""Workload generation following the paper's methodology (§8.3): requests
sampled with Gamma-distributed inter-arrival times controlled by (RPS, CV);
model instances mapped to Azure-trace functions round-robin, which yields a
skewed per-model popularity — approximated here with a Zipf law."""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


@dataclass
class Request:
    req_id: int
    model: str
    app: str
    arrival: float
    prompt_tokens: int
    output_tokens: int
    slo_ttft: float
    slo_tpot: float
    # filled by the serving system:
    first_token: Optional[float] = None
    completion: Optional[float] = None
    tokens_done: int = 0
    # arrived with no ready endpoint (experienced a cold start / queued
    # behind one) — set by the serving system at admission
    cold: Optional[bool] = None
    # multi-turn conversations (the KV-aware router's workload): turns of
    # one session share a growing prompt prefix, so routing them to the
    # replica holding the session's KV blocks skips most of the prefill
    session: Optional[int] = None
    turn: int = 0
    prompt_ids: Optional[List[int]] = None   # concrete ids, when generated

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token is None else self.first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.completion is None or self.output_tokens <= 1:
            return 0.0 if self.completion is not None else None
        return (self.completion - self.first_token) / (self.output_tokens - 1)

    def ttft_ok(self) -> bool:
        return self.ttft is not None and self.ttft <= self.slo_ttft + 1e-9

    def tpot_ok(self) -> bool:
        t = self.tpot
        return t is not None and t <= self.slo_tpot + 1e-9


@dataclass(frozen=True)
class ModelInstance:
    """One user deployment (the paper creates 64 instances per app)."""
    name: str          # unique instance name, e.g. chatbot-7b#3
    app: str
    base_model: str
    slo_ttft: float
    slo_tpot: float
    mean_prompt: int
    mean_output: int
    popularity: float = 1.0


def make_instances(applications, n_per_app: int, slo_scale: float = 1.0
                   ) -> List[ModelInstance]:
    out = []
    for app in applications:
        for i in range(n_per_app):
            out.append(ModelInstance(
                name=f"{app.name}#{i}", app=app.name,
                base_model=app.model,
                slo_ttft=app.slo.ttft * slo_scale,
                slo_tpot=app.slo.tpot * slo_scale,
                mean_prompt=app.mean_prompt,
                mean_output=app.mean_output))
    return out


def generate(instances: Sequence[ModelInstance], rps: float, cv: float,
             duration: float, seed: int = 0, zipf_a: float = 1.1
             ) -> List[Request]:
    """Gamma arrivals: shape k = 1/CV^2, mean 1/rps. Instance choice ~ Zipf."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / (cv * cv)
    scale = (1.0 / rps) / shape
    n_inst = len(instances)
    ranks = np.arange(1, n_inst + 1, dtype=np.float64)
    pop = ranks ** (-zipf_a)
    pop /= pop.sum()
    perm = rng.permutation(n_inst)           # which instance gets which rank

    reqs: List[Request] = []
    t = 0.0
    rid = 0
    while True:
        t += rng.gamma(shape, scale)
        if t >= duration:
            break
        inst = instances[perm[rng.choice(n_inst, p=pop)]]
        prompt = max(8, int(rng.lognormal(math.log(inst.mean_prompt), 0.6)))
        output = max(4, int(rng.lognormal(math.log(inst.mean_output), 0.6)))
        reqs.append(Request(rid, inst.name, inst.app, t,
                            min(prompt, 16384), min(output, 4096),
                            inst.slo_ttft, inst.slo_tpot))
        rid += 1
    return reqs


def multi_turn_sessions(instance: ModelInstance, n_sessions: int,
                        turns: int, *, first_prompt: int = 32,
                        turn_tokens: int = 16, vocab: int = 512,
                        session_rps: float = 0.5, think_s: float = 2.0,
                        cv: float = 1.0, seed: int = 0) -> List[Request]:
    """K-turn chat sessions against one model instance — the workload a
    KV-aware router wins on. Each session opens with ``first_prompt``
    random tokens; every later turn *re-sends the full conversation so
    far* plus ``turn_tokens`` fresh ones, so turn ``k``'s prompt is a
    strict prefix-extension of turn ``k-1``'s and the shared prefix
    grows with the conversation. Sessions open with Gamma(CV) arrivals
    at ``session_rps``; turns within a session are spaced by an
    exponential think time with mean ``think_s``.

    Token ids are sampled uniformly from ``[0, vocab)`` — keep ``vocab``
    at/below the serving model's vocabulary (ids past it index nothing
    and poison the KV cache with NaNs on any engine). ``prompt_ids``
    carries the concrete ids; ``session``/``turn`` label the
    conversation."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / (cv * cv)
    scale = (1.0 / session_rps) / shape
    reqs: List[Request] = []
    rid = 0
    t_open = 0.0
    for s in range(n_sessions):
        t_open += rng.gamma(shape, scale)
        history = [int(x) for x in rng.integers(0, vocab, first_prompt)]
        t = t_open
        for k in range(turns):
            if k > 0:
                t += rng.exponential(think_s)
                history = history + [int(x) for x in
                                     rng.integers(0, vocab, turn_tokens)]
            reqs.append(Request(rid, instance.name, instance.app, t,
                                len(history), instance.mean_output,
                                instance.slo_ttft, instance.slo_tpot,
                                session=s, turn=k,
                                prompt_ids=list(history)))
            rid += 1
    reqs.sort(key=lambda r: (r.arrival, r.req_id))
    return reqs


def burst(instance: ModelInstance, n: int, at: float = 0.0) -> List[Request]:
    """n simultaneous requests to one model (Fig. 14 scale-up experiment)."""
    return [Request(i, instance.name, instance.app, at,
                    instance.mean_prompt, instance.mean_output,
                    instance.slo_ttft, instance.slo_tpot)
            for i in range(n)]


def periodic_bursts(instances: Sequence[ModelInstance], period: float,
                    n_bursts: int, burst_size: int, *,
                    stagger: float = 2.0, start: float = 1.0,
                    jitter: float = 0.0, seed: int = 0) -> List[Request]:
    """Recurring multi-model burst trace (the fleet benchmark's workload):
    instance ``j`` bursts ``burst_size`` simultaneous requests at
    ``start + j*stagger + k*period`` for ``k < n_bursts``, optionally
    jittered. This is the serverless pattern HydraServe's predictive
    prewarming targets — each model goes fully idle between bursts, so a
    purely reactive fleet pays a cold start per episode."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    rid = 0
    for k in range(n_bursts):
        for j, inst in enumerate(instances):
            at = start + j * stagger + k * period
            if jitter > 0:
                at = max(0.0, at + rng.normal(0.0, jitter))
            for _ in range(burst_size):
                reqs.append(Request(rid, inst.name, inst.app, at,
                                    inst.mean_prompt, inst.mean_output,
                                    inst.slo_ttft, inst.slo_tpot))
                rid += 1
    reqs.sort(key=lambda r: r.arrival)
    return reqs
