"""Pipeline consolidation (§6): scale-down / scale-up policy and the
sliding-window worker-count predictor.

Mechanics (background fetch of remaining parts, KV migration) live in
serving/; this module is the *policy*: how many standalone workers a
pipeline group should consolidate into.
"""

from __future__ import annotations

import collections
import math
from dataclasses import dataclass
from typing import Deque, Dict, List, Tuple


@dataclass(frozen=True)
class ConsolidationPlan:
    mode: str               # 'down' | 'up'
    keep_workers: int       # standalone workers the group becomes
    group_sizes: Tuple[int, ...]   # pipeline groups to create on cold start


class SlidingWindowPredictor:
    """Per-model arrival predictor (§6.1): the request count of the previous
    window is the predicted maximum for the next."""

    def __init__(self, window_s: float = 60.0):
        self.window_s = window_s
        self._arrivals: Dict[str, Deque[float]] = collections.defaultdict(
            collections.deque)

    def record(self, model: str, now: float):
        q = self._arrivals[model]
        q.append(now)
        self._trim(q, now)

    def _trim(self, q: Deque[float], now: float):
        while q and q[0] < now - self.window_s:
            q.popleft()

    def predicted_next_window(self, model: str, now: float) -> int:
        q = self._arrivals[model]
        self._trim(q, now)
        return len(q)


class ConsolidationPolicy:
    """Sizes cold-start groups and picks scale-down vs scale-up."""

    def __init__(self, predictor: SlidingWindowPredictor,
                 per_worker_capacity: int = 8):
        self.predictor = predictor
        self.per_worker_capacity = per_worker_capacity

    def required_workers(self, model: str, queue_len: int, now: float) -> int:
        """Workers needed = (waiting requests + predicted arrivals) /
        per-worker batch capacity (§6.1)."""
        predicted = self.predictor.predicted_next_window(model, now)
        return max(1, math.ceil((queue_len + predicted)
                                / self.per_worker_capacity))

    def plan(self, model: str, queue_len: int, now: float,
             max_pp: int, current_workers: int = 0) -> ConsolidationPlan:
        """Decide group shape for a cold start and the consolidation target.

        Default is scale-DOWN (one standalone worker remains). Under burst
        (required > current+1) switch to scale-UP: create pipeline groups
        covering the deficit; every member later becomes standalone.
        """
        assert max_pp >= 1
        required = self.required_workers(model, queue_len, now)
        deficit = max(1, required - current_workers)
        if deficit <= 1:
            # widest pipeline the placement allows: fastest cold start,
            # consolidating down to one standalone worker afterwards
            return ConsolidationPlan("down", 1, (max_pp,))
        groups: List[int] = []
        remaining = deficit
        while remaining > 0:
            g = min(max_pp, remaining)
            groups.append(g)
            remaining -= g
        return ConsolidationPlan("up", deficit, tuple(groups))
