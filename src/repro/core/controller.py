"""Cluster-level central controller: glues Algorithm 1 (parallelism size
selection), Algorithm 2 (contention tracking) and the consolidation policy.
Used by both the discrete-event serving simulation and the real JAX engine.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.consolidation import (ConsolidationPolicy,
                                      SlidingWindowPredictor)
from repro.core.parallelism import predict_tpot, select_scheme
from repro.core.placement import ContentionTracker
from repro.core.types import ColdStartScheme, ModelProfile, ServerSpec, SLO


class CentralController:
    def __init__(self, servers: Dict[str, ServerSpec],
                 window_s: float = 60.0, per_worker_capacity: int = 8,
                 overlapped: bool = True, max_pp_cap: Optional[int] = None):
        self.servers = servers
        self.tracker = ContentionTracker(servers)
        self.predictor = SlidingWindowPredictor(window_s)
        self.consolidation = ConsolidationPolicy(self.predictor,
                                                 per_worker_capacity)
        self.overlapped = overlapped
        self.max_pp_cap = max_pp_cap
        self.models: Dict[str, ModelProfile] = {}

    # ------------------------------------------------------------ registry
    def register_model(self, profile: ModelProfile):
        self.models[profile.name] = profile

    def record_request(self, model: str, now: float):
        self.predictor.record(model, now)

    # ------------------------------------------------------- cold starts
    def plan_cold_start(self, model_name: str,
                        free_hbm: Optional[Dict[str, int]] = None,
                        now: float = 0.0, queue_wait: float = 0.0,
                        force_s: Optional[int] = None) -> ColdStartScheme:
        if free_hbm is None:              # idle cluster: all HBM available
            free_hbm = {sid: s.hbm_bytes for sid, s in self.servers.items()}
        model = self.models[model_name]
        if self.max_pp_cap is not None:
            model = dataclasses.replace(
                model, max_pp=min(model.max_pp, self.max_pp_cap))
        eff = self.tracker.effective_bandwidths(now)
        return select_scheme(model, self.servers, free_hbm, eff,
                             t_w=queue_wait, overlapped=self.overlapped,
                             fixed_s=force_s)

    def fetch_deadline(self, model_name: str, scheme: ColdStartScheme,
                       now: float) -> float:
        """Alg.2: D_i from the TTFT SLO — fetch must complete early enough
        to leave room for the prefill chain (+ load slack when not
        overlapped)."""
        model = self.models[model_name]
        t = model.timings
        post = t.t_p * (scheme.s - scheme.w + scheme.w / scheme.s) \
            + t.t_n * scheme.s
        d = now + model.slo.ttft - post
        # never earlier than the uncontended fetch itself
        min_fetch = min(
            (model.size_bytes / scheme.s) / self.servers[sid].nic_bytes_per_s
            for sid in scheme.servers)
        return max(d, now + min_fetch)

    def admit_fetches(self, model_name: str, scheme: ColdStartScheme,
                      worker_ids, stage_bytes, now: float) -> float:
        """Register each stage fetch with the contention tracker; returns
        the common deadline."""
        deadline = self.fetch_deadline(model_name, scheme, now)
        for sid, wid, nbytes in zip(scheme.servers, worker_ids, stage_bytes):
            self.tracker.admit(sid, wid, nbytes, deadline, now)
        return deadline

    def fetch_complete(self, server_id: str, worker_id: str, now: float):
        self.tracker.complete(server_id, worker_id, now)

    # --------------------------------------------------------- autoscaling
    def consolidation_plan(self, model_name: str, queue_len: int, now: float,
                           current_workers: int):
        model = self.models[model_name]
        return self.consolidation.plan(model_name, queue_len, now,
                                       model.max_pp, current_workers)
