"""Cluster-level central controller: glues Algorithm 1 (parallelism size
selection), Algorithm 2 (contention tracking), the consolidation policy,
and the fleet-wide placement registry behind Alg. 1 proactive model
distribution. Used by both the discrete-event serving simulation and the
real JAX engine (the ``FleetController`` in repro/fleet drives the same
instance for either data plane).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.consolidation import (ConsolidationPolicy,
                                      SlidingWindowPredictor)
from repro.core.parallelism import NoPlacement, predict_tpot, select_scheme
from repro.core.placement import ContentionTracker
from repro.core.types import ColdStartScheme, ModelProfile, ServerSpec, SLO


class CentralController:
    def __init__(self, servers: Dict[str, ServerSpec],
                 window_s: float = 60.0, per_worker_capacity: int = 8,
                 overlapped: bool = True, max_pp_cap: Optional[int] = None):
        self.servers = servers
        self.tracker = ContentionTracker(servers)
        self.predictor = SlidingWindowPredictor(window_s)
        self.consolidation = ConsolidationPolicy(self.predictor,
                                                 per_worker_capacity)
        self.overlapped = overlapped
        self.max_pp_cap = max_pp_cap
        self.models: Dict[str, ModelProfile] = {}
        # fleet-wide placement state: model -> {server_id: tier_name}.
        # Written by Alg. 1 proactive distribution, read by cold-start
        # planning (seeded servers fetch from fast tiers) and the fleet
        # benchmark's placement accounting.
        self.placements: Dict[str, Dict[str, str]] = {}

    # ------------------------------------------------------------ registry
    def register_model(self, profile: ModelProfile):
        self.models[profile.name] = profile

    def record_request(self, model: str, now: float):
        self.predictor.record(model, now)

    # ----------------------------------------------------------- placement
    def record_placement(self, model: str, server_id: str,
                         tier: str = "peer"):
        self.placements.setdefault(model, {})[server_id] = tier

    def drop_placement(self, model: str, server_id: Optional[str] = None):
        if server_id is None:
            self.placements.pop(model, None)
        else:
            self.placements.get(model, {}).pop(server_id, None)

    def placed_servers(self, model: str) -> List[str]:
        return list(self.placements.get(model, {}))

    def placement_tier(self, model: str, server_id: str) -> Optional[str]:
        return self.placements.get(model, {}).get(server_id)

    def plan_distribution(self, ranked_models: Sequence[str],
                          fanout: int = 2) -> List[Tuple[str, str]]:
        """Alg. 1 proactive model distribution: walk the demand-ranked
        models and give each up to ``fanout`` placement targets, spreading
        over distinct servers fattest-NIC-first so hot models land where
        a cold start fetches fastest. Already-seeded (model, server) pairs
        are skipped; servers are load-balanced by how many placements they
        already hold. Returns the new (model, server_id) seedings — the
        caller executes them (host-cache fetch in the sim, a
        ``ModelStore.place`` tier in the real data plane)."""
        load = {sid: 0 for sid in self.servers}
        for placed in self.placements.values():
            for sid in placed:
                if sid in load:
                    load[sid] += 1
        order = sorted(self.servers,
                       key=lambda sid: (-self.servers[sid].nic_bytes_per_s,
                                        sid))
        out: List[Tuple[str, str]] = []
        for name in ranked_models:
            have = set(self.placed_servers(name))
            want = fanout - len(have)
            for sid in sorted(order, key=lambda sid: load[sid]):
                if want <= 0:
                    break
                if sid in have:
                    continue
                out.append((name, sid))
                load[sid] += 1
                want -= 1
        return out

    # ------------------------------------------------------- cold starts
    def plan_cold_start(self, model_name: str,
                        free_hbm: Optional[Dict[str, int]] = None,
                        now: float = 0.0, queue_wait: float = 0.0,
                        force_s: Optional[int] = None,
                        prefer: Optional[Sequence[str]] = None
                        ) -> ColdStartScheme:
        """Alg. 1 scheme selection. With ``prefer`` (e.g. the model's
        proactively-seeded servers) planning is tried on that restricted
        pool first — a feasible scheme on seeded servers beats one on the
        open pool because its fetches come from a fast tier — falling
        back to the whole cluster when the preferred pool can't host."""
        if free_hbm is None:              # idle cluster: all HBM available
            free_hbm = {sid: s.hbm_bytes for sid, s in self.servers.items()}
        model = self.models[model_name]
        if self.max_pp_cap is not None:
            model = dataclasses.replace(
                model, max_pp=min(model.max_pp, self.max_pp_cap))
        eff = self.tracker.effective_bandwidths(now)
        if prefer:
            sub = {sid: self.servers[sid] for sid in prefer
                   if sid in self.servers}
            if sub:
                try:
                    return select_scheme(
                        model, sub,
                        {sid: free_hbm.get(sid, 0) for sid in sub},
                        {sid: eff[sid] for sid in sub},
                        t_w=queue_wait, overlapped=self.overlapped,
                        fixed_s=force_s)
                except NoPlacement:
                    pass
        return select_scheme(model, self.servers, free_hbm, eff,
                             t_w=queue_wait, overlapped=self.overlapped,
                             fixed_s=force_s)

    def fetch_deadline(self, model_name: str, scheme: ColdStartScheme,
                       now: float) -> float:
        """Alg.2: D_i from the TTFT SLO — fetch must complete early enough
        to leave room for the prefill chain (+ load slack when not
        overlapped)."""
        model = self.models[model_name]
        t = model.timings
        post = t.t_p * (scheme.s - scheme.w + scheme.w / scheme.s) \
            + t.t_n * scheme.s
        d = now + model.slo.ttft - post
        # never earlier than the uncontended fetch itself
        min_fetch = min(
            (model.size_bytes / scheme.s) / self.servers[sid].nic_bytes_per_s
            for sid in scheme.servers)
        return max(d, now + min_fetch)

    def admit_fetches(self, model_name: str, scheme: ColdStartScheme,
                      worker_ids, stage_bytes, now: float) -> float:
        """Register each stage fetch with the contention tracker; returns
        the common deadline."""
        deadline = self.fetch_deadline(model_name, scheme, now)
        for sid, wid, nbytes in zip(scheme.servers, worker_ids, stage_bytes):
            self.tracker.admit(sid, wid, nbytes, deadline, now)
        return deadline

    def fetch_complete(self, server_id: str, worker_id: str, now: float):
        self.tracker.complete(server_id, worker_id, now)

    # --------------------------------------------------------- autoscaling
    def consolidation_plan(self, model_name: str, queue_len: int, now: float,
                           current_workers: int):
        model = self.models[model_name]
        return self.consolidation.plan(model_name, queue_len, now,
                                       model.max_pp, current_workers)
