"""The paper's primary contribution: pipeline-parallel cold starts
(Alg. 1 size selection, Alg. 2 contention-aware placement, worker-level
overlapping, pipeline consolidation)."""

from repro.core.coldstart import (OverlapFlags, group_tpot, group_ttft,  # noqa: F401
                                  worker_timeline)
from repro.core.consolidation import (ConsolidationPlan,  # noqa: F401
                                      ConsolidationPolicy,
                                      SlidingWindowPredictor)
from repro.core.controller import CentralController  # noqa: F401
from repro.core.parallelism import (predict_tpot, predict_ttft,  # noqa: F401
                                    predict_ttft_overlapped, select_scheme)
from repro.core.placement import ContentionTracker  # noqa: F401
from repro.core.types import (GB, Gbps, ColdStartScheme,  # noqa: F401
                              ModelProfile, ServerSpec, SLO, TimingProfile)
