"""Shared datatypes for the cold-start controller."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

GB = 1 << 30
Gbps = 1e9 / 8           # bytes/sec per Gbit/s


@dataclass
class SLO:
    ttft: float                      # seconds
    tpot: float                      # seconds / token

    def scaled(self, f: float) -> "SLO":
        return SLO(self.ttft * f, self.tpot * f)


@dataclass
class TimingProfile:
    """Historical per-model / per-platform timings (paper §4.1.2, §5.2).

    Defaults calibrated so model fetching dominates (paper Fig. 1; a
    Llama2-7B cold start on a contended 16 Gbps NIC reaches ~25-40 s, of
    which fetch is the largest stage; Table 1 supplies warm latencies).
    """
    t_cc: float = 2.0                # container creation
    t_l: float = 2.5                 # library loading (CPU-bound)
    t_cu: float = 0.5                # accelerator context init
    t_n: float = 0.010               # per-hop activation transmission
    t_p: float = 1.5                 # full prefill, warm, full memory
    t_d: float = 0.042               # per-token decode, warm, full memory

    @property
    def t_c(self) -> float:
        """Aggregate container+runtime init used by the non-overlapped Eq.1."""
        return self.t_cc + self.t_l + self.t_cu


@dataclass
class ServerSpec:
    server_id: str
    nic_bytes_per_s: float           # b_i
    pcie_bytes_per_s: float          # p_i
    hbm_bytes: int                   # accelerator memory per server
    n_devices: int = 1


@dataclass
class ColdWorkerRecord:
    """Alg.2 bookkeeping entry: one in-flight cold-start fetch on a server."""
    worker_id: str
    deadline: float                  # D_i (absolute time)
    pending_bytes: float             # S_i


@dataclass
class ColdStartScheme:
    """Output of Algorithm 1."""
    s: int                           # pipeline parallelism size
    w: int                           # number of full-memory workers
    servers: Tuple[str, ...]         # one per worker (first w full-memory)
    predicted_ttft: float
    predicted_tpot: float
    slo_ok: bool

    @property
    def full_memory(self) -> Tuple[bool, ...]:
        return tuple(i < self.w for i in range(self.s))


@dataclass
class ModelProfile:
    """What the controller knows about a registered model."""
    name: str
    size_bytes: int
    timings: TimingProfile
    slo: SLO
    max_pp: int = 4
    # HBM a *warm, non-parallelized* worker reserves (weights + KV + runtime)
    full_hbm_bytes: Optional[int] = None
    # per-token KV footprint (all layers); None = geometry unknown, callers
    # fall back to their own default (see kv_bytes_from_geometry)
    kv_bytes_per_token: Optional[int] = None

    @staticmethod
    def kv_bytes_from_geometry(n_attn_layers: int, n_kv_heads: int,
                               head_dim: int, dtype_bytes: int = 2) -> int:
        """KV bytes one token pins across the whole model: K and V, every
        attention layer — 2 * layers * kv_heads * head_dim * dtype."""
        return 2 * n_attn_layers * n_kv_heads * head_dim * dtype_bytes

    def hbm_full(self) -> int:
        if self.full_hbm_bytes is not None:
            return self.full_hbm_bytes
        return int(self.size_bytes * 1.25)     # weights + KV/activations slack

    def hbm_low(self, s: int) -> int:
        return max(self.hbm_full() // s, 1)
