"""Algorithm 1 — pipeline-parallelism size selection, with the paper's TTFT /
TPOT predictors (Eq. 1, Eq. 2, Eq. 5)."""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.types import (ColdStartScheme, ModelProfile, ServerSpec, SLO,
                              TimingProfile)


class NoPlacement(RuntimeError):
    """No server set can currently host the model (HBM pressure)."""


def _ratio(b: float, p: float) -> float:
    return 1.0 / b + 1.0 / p


def predict_ttft(M: float, s: int, w: int, ratios: Sequence[float],
                 t: TimingProfile, t_w: float = 0.0) -> float:
    """Eq. 1 — non-overlapped cold-start TTFT."""
    max_ratio = max(ratios)
    return (t_w + t.t_c + (M / s) * max_ratio
            + t.t_p * (s - w + w / s) + t.t_n * s)


def predict_ttft_overlapped(M: float, s: int, w: int,
                            bandwidths: Sequence[float],
                            pcies: Sequence[float],
                            t: TimingProfile, t_w: float = 0.0) -> float:
    """Eq. 5 — TTFT with worker-level overlapping (§5).

    Per worker: ready = max(container-path, fetch-path) where the container
    path is t_cc + t_cu + max(load, t_l) (library loading overlapped with
    host->device loading) and the fetch path is (M/s)/b_i (prefetch starts
    at t=0, pipelined with loading at tensor granularity).
    """
    per_worker = [
        max(t.t_cc + t.t_cu + max((M / s) / p, t.t_l), (M / s) / b)
        for b, p in zip(bandwidths, pcies)
    ]
    return (t_w + max(per_worker)
            + t.t_p * (s - w + w / s) + t.t_n * s)


def predict_tpot(s: int, w: int, t: TimingProfile) -> float:
    """Eq. 2 — decode latency of the pipeline group. A full-memory worker
    contributes t_d/s per hop, a low-memory worker a full t_d."""
    if s == 1:
        return t.t_d
    return t.t_d * (s - w + w / s) + t.t_n * s


def select_scheme(
    model: ModelProfile,
    servers: Dict[str, ServerSpec],
    free_hbm: Dict[str, int],
    effective_bw: Dict[str, float],
    t_w: float = 0.0,
    overlapped: bool = True,
    slo: Optional[SLO] = None,
    fixed_s: Optional[int] = None,
) -> ColdStartScheme:
    """Algorithm 1.

    ``effective_bw`` is the per-server bandwidth the Alg.2 tracker grants a
    *new* cold-start worker right now (0 => the server must not be used).
    Enumerates (s, w) in minimal-resource order and returns the first scheme
    meeting both SLOs; falls back to the feasible scheme with minimal
    predicted TTFT (paper falls back to a single worker).
    """
    slo = slo or model.slo
    t = model.timings
    M = model.size_bytes

    usable = [sid for sid, spec in servers.items()
              if effective_bw.get(sid, spec.nic_bytes_per_s) > 0]

    def ratio_of(sid: str) -> float:
        spec = servers[sid]
        return _ratio(effective_bw.get(sid, spec.nic_bytes_per_s),
                      spec.pcie_bytes_per_s)

    best_fallback: Optional[ColdStartScheme] = None

    s_range = [fixed_s] if fixed_s else range(1, model.max_pp + 1)
    for s in s_range:
        for w in range(0, s + 1):
            # servers that fit a full-memory worker (paper: "fit a model of
            # size M"), best fetch+load ratio first
            full_ok = sorted(
                (sid for sid in usable if free_hbm[sid] >= model.hbm_full()),
                key=ratio_of)
            if len(full_ok) < w:
                continue
            chosen_full = full_ok[:w]
            # low-memory candidates: fit M/s; merge leftover full-capable
            # servers in (paper's MergeSort), keep ascending ratio. (The
            # pseudocode prints "descending" for {j}; that contradicts the
            # max-ratio TTFT term, so we sort ascending — see DESIGN.md §9.)
            rest = [sid for sid in usable
                    if sid not in chosen_full
                    and free_hbm[sid] >= model.hbm_low(s)]
            # tie-break: prefer servers that could later host the FULL
            # model, so scale-down consolidation has an upgrade target
            rest.sort(key=lambda sid: (ratio_of(sid),
                                       free_hbm[sid] < model.hbm_full()))
            if len(rest) < s - w:
                continue
            chosen_low = rest[: s - w]
            g = tuple(chosen_full + chosen_low)
            bws = [effective_bw.get(sid, servers[sid].nic_bytes_per_s)
                   for sid in g]
            pcs = [servers[sid].pcie_bytes_per_s for sid in g]
            if overlapped:
                ttft = predict_ttft_overlapped(M, s, w, bws, pcs, t, t_w)
            else:
                ttft = predict_ttft(M, s, w,
                                    [_ratio(b, p) for b, p in zip(bws, pcs)],
                                    t, t_w)
            tpot = predict_tpot(s, w, t)
            scheme = ColdStartScheme(s, w, g, ttft, tpot, slo_ok=True)
            if ttft <= slo.ttft and tpot <= slo.tpot:
                return scheme
            # fallback preference: never trade TPOT away (the paper's
            # fallback is a single full worker, which is TPOT-clean)
            cand = ColdStartScheme(s, w, g, ttft, tpot, slo_ok=False)
            if best_fallback is None:
                best_fallback = cand
            else:
                best_ok = best_fallback.predicted_tpot <= slo.tpot
                cand_ok = tpot <= slo.tpot
                if (cand_ok, -ttft) > (best_ok, -best_fallback.predicted_ttft):
                    best_fallback = cand

    if best_fallback is None:
        raise NoPlacement(
            f"no placement fits model {model.name} "
            f"({model.size_bytes >> 20} MiB) on any server")
    return best_fallback
