"""Worker-level overlapping (§5): the cold-start stage timeline.

``worker_timeline`` composes the six stages of Fig. 1 under the optimization
flags of Fig. 9 (+Prefetch / +Stream / +Overlap); ``group_ttft`` adds the
pipeline-level prefill terms. The cluster simulator supplies
contention-accurate fetch durations; the analytic callers use bytes/bw.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.core.types import TimingProfile


@dataclass(frozen=True)
class OverlapFlags:
    """Which worker-level optimizations are on (Fig. 9's ablation axis)."""
    prefetch: bool = True      # node-level prefetcher: fetch starts at t=0
    stream: bool = True        # fetch->load pipelined at tensor granularity
    overlap_load: bool = True  # accel-ctx first; lib load || model load

    @staticmethod
    def none() -> "OverlapFlags":
        return OverlapFlags(False, False, False)

    @staticmethod
    def all() -> "OverlapFlags":
        return OverlapFlags(True, True, True)


@dataclass
class WorkerTimeline:
    ready: float
    spans: Dict[str, Tuple[float, float]] = field(default_factory=dict)


def worker_timeline(t: TimingProfile, fetch_seconds: float,
                    load_seconds: float,
                    flags: OverlapFlags = OverlapFlags.all(),
                    start: float = 0.0) -> WorkerTimeline:
    """Absolute stage spans for one cold-start worker (relative to `start`).

    Rules:
      * fetch begins at t=0 with prefetch, else after runtime init.
      * without overlap_load the runtime path is cc -> lib -> cuda; with it
        cc -> cuda (prioritized) and lib runs parallel to model loading.
      * loading needs the device context; with stream it consumes tensors as
        they arrive, so load_end = max(fetch_end, load_begin + load).
      * inference additionally needs libraries: ready = max(load_end, lib_end)
    """
    spans: Dict[str, Tuple[float, float]] = {}
    cc_end = start + t.t_cc
    spans["container"] = (start, cc_end)

    if flags.overlap_load:
        cuda_end = cc_end + t.t_cu
        lib_end = cuda_end + t.t_l          # runs concurrent with loading
        spans["cuda"] = (cc_end, cuda_end)
        spans["lib"] = (cuda_end, lib_end)
    else:
        lib_end = cc_end + t.t_l
        cuda_end = lib_end + t.t_cu
        spans["lib"] = (cc_end, lib_end)
        spans["cuda"] = (lib_end, cuda_end)

    if flags.prefetch:
        fetch_start = start
    else:
        # classic workflow: fetch only after the full runtime init,
        # whichever order (lib/cuda) the flags put it in
        fetch_start = max(lib_end, cuda_end)
    fetch_end = fetch_start + fetch_seconds
    spans["fetch"] = (fetch_start, fetch_end)

    load_begin = max(cuda_end, fetch_start)
    if flags.stream:
        load_end = max(fetch_end, load_begin + load_seconds)
    else:
        load_end = max(fetch_end, load_begin) + load_seconds
    spans["load"] = (load_begin, load_end)

    ready = max(load_end, lib_end)
    assert all(s0 <= s1 for s0, s1 in spans.values())
    if not flags.prefetch:
        # fetch must not overlap ANY runtime-init stage span: the classic
        # workflow downloads only once container + lib + cuda are all
        # done. Checked against the recorded spans (not the locals that
        # defined fetch_start) so a future reordering of the init stages
        # can't silently start the fetch early.
        for stage in ("container", "lib", "cuda"):
            assert spans["fetch"][0] >= spans[stage][1], \
                f"no-prefetch fetch overlaps runtime init stage {stage!r}"
    assert ready >= max(s1 for _, s1 in spans.values()) - 1e-12
    return WorkerTimeline(ready=ready, spans=spans)


def group_ttft(worker_ready: Tuple[float, ...], s: int, w: int,
               t: TimingProfile) -> float:
    """First token time for a pipeline group: slowest worker + prefill chain
    (full-memory worker: t_p/s per stage; low-memory: t_p) + s activation
    hops (Eq. 1/5 prefill terms)."""
    prefill = t.t_p * (s - w + w / s) + t.t_n * s if s > 1 else t.t_p
    return max(worker_ready) + prefill


def group_tpot(s: int, w: int, t: TimingProfile) -> float:
    if s == 1:
        return t.t_d
    return t.t_d * (s - w + w / s) + t.t_n * s
