"""Algorithm 2 — network-contention-aware worker placement.

Per server the tracker keeps the in-flight cold-start fetches (deadline D_i,
pending bytes S_i).  Admission check (Eq. 3): with N residents and one
candidate, every resident must still finish under fair share B/(N+1).
Pending bytes are re-estimated lazily on every bandwidth-changing event
(Eq. 4): S_i' = S_i - B/N * (T - T').
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.types import ColdWorkerRecord, ServerSpec


_DONE_EPS = 1e-6                     # bytes: below this a fetch is finished


@dataclass
class _NodeState:
    spec: ServerSpec
    workers: Dict[str, ColdWorkerRecord] = field(default_factory=dict)
    last_change: float = 0.0
    finish_log: Dict[str, float] = field(default_factory=dict)


class ContentionTracker:
    """Cluster-level bookkeeping behind GETNODEBANDWIDTH /
    HANDLEBANDWIDTHCHANGE in the paper's Algorithm 2."""

    def __init__(self, servers: Dict[str, ServerSpec]):
        self._nodes = {sid: _NodeState(spec) for sid, spec in servers.items()}

    # ----------------------------------------------------------- internals
    def _settle(self, node: _NodeState, now: float):
        """Eq. 4: advance pending sizes to `now`. Every fetch completion is
        itself a bandwidth-change event, so the interval is walked
        iteratively in finish-time order: when a resident's pending bytes
        hit zero mid-interval, the survivors' share steps up to B/(n-1)
        for the remainder — settling the whole interval at the stale B/n
        would undercharge them the freed tail bandwidth. Completion times
        are recorded in ``finish_log`` (queryable via ``finish_time``)."""
        if now <= node.last_change:
            return
        t = node.last_change
        while node.workers and t < now:
            share = node.spec.nic_bytes_per_s / len(node.workers)
            min_pending = min(w.pending_bytes for w in node.workers.values())
            t_fin = t + max(min_pending, 0.0) / share
            step_end = min(t_fin, now)
            dt = max(step_end - t, 0.0)
            done = []
            for w in node.workers.values():
                w.pending_bytes -= share * dt
                if w.pending_bytes <= _DONE_EPS:
                    done.append(w.worker_id)
            if not done and step_end <= t:
                # the residual min pending cannot advance the clock at
                # float resolution (t + dt == t): it is done *now* —
                # without this the loop would spin forever
                done = [w.worker_id for w in node.workers.values()
                        if w.pending_bytes <= min_pending + _DONE_EPS]
            for wid in done:
                node.finish_log[wid] = step_end
                del node.workers[wid]
            if not done and step_end >= now:
                break
            t = step_end
        node.last_change = now

    # ------------------------------------------------------------- queries
    def node_bandwidth(self, server_id: str, now: float) -> float:
        """Effective NIC share a NEW cold-start worker would get on this
        server right now; 0 if admitting it would break Eq. 3 for any
        resident fetch. (Paper's GETNODEBANDWIDTH returns B/N which is
        undefined at N=0 and optimistic otherwise; we return B/(N+1),
        consistent with the Eq. 3 check — noted in DESIGN.md §9.)"""
        node = self._nodes[server_id]
        self._settle(node, now)
        b = node.spec.nic_bytes_per_s
        n = len(node.workers)
        share_after = b / (n + 1)
        for w in node.workers.values():
            if w.pending_bytes > share_after * (w.deadline - now):
                return 0.0
        return share_after

    def effective_bandwidths(self, now: float) -> Dict[str, float]:
        return {sid: self.node_bandwidth(sid, now) for sid in self._nodes}

    def residents(self, server_id: str) -> List[ColdWorkerRecord]:
        return list(self._nodes[server_id].workers.values())

    # ------------------------------------------------------------ mutation
    def admit(self, server_id: str, worker_id: str, fetch_bytes: float,
              deadline: float, now: float):
        node = self._nodes[server_id]
        self._settle(node, now)
        # a re-admitted worker id starts a new fetch: its old completion
        # record is stale (also bounds finish_log growth for id reuse)
        node.finish_log.pop(worker_id, None)
        node.workers[worker_id] = ColdWorkerRecord(worker_id, deadline,
                                                   float(fetch_bytes))

    def complete(self, server_id: str, worker_id: str, now: float):
        """Fetch finished (or worker aborted) — a bandwidth change event."""
        node = self._nodes[server_id]
        self._settle(node, now)
        if node.workers.pop(worker_id, None) is not None:
            node.finish_log[worker_id] = now

    def finish_time(self, server_id: str, worker_id: str) -> Optional[float]:
        """When the fluid model saw this fetch complete (None if still
        pending / unknown). Populated by ``_settle`` at the exact
        fair-share completion instant, or by an explicit ``complete``."""
        return self._nodes[server_id].finish_log.get(worker_id)

    def fair_share(self, server_id: str, now: float) -> float:
        """Current fair share among residents (simulation ground truth)."""
        node = self._nodes[server_id]
        self._settle(node, now)
        n = max(len(node.workers), 1)
        return node.spec.nic_bytes_per_s / n
