"""Multi-tier KV block store: the tiers *below* the HBM page pool.

HydraServe's serving engines keep KV in a paged HBM pool
(serving/kvcache.py). Under pool pressure refcount-zero cached blocks
are LRU-evicted — historically the bytes were simply lost and a later
prefix hit re-prefilled them. ``KVBlockStore`` catches those evictions
instead (the engine's spill hook reads the page content *at* the evict
notification, before the block id is reused) and keeps them in two
further tiers:

  * **host** — live numpy arrays under a bounded block budget,
    restore charged at PCIe class bandwidth;
  * **segment** — a serialized ``KVSegmentStore`` (repro/store/) the
    host tier demotes its own LRU overflow into, restore charged at
    remote class bandwidth.

Every restore is accounted as a **measured flow** on the shared
``FetchSchedule`` — the same Alg. 2 contention-fair machinery model
fetches use — so a KV restore racing a cold start on one server divides
the NIC exactly like two stage fetches would, and
``restore_estimate`` quotes the modeled transfer time a router can hold
against the cost of re-prefilling the same tokens.

The store is **content-addressed by block-chain hash** and therefore
shareable across all replicas of one model: a block spilled by replica
A restores into replica B's pool bit-exactly (payloads are keyed by
global attention period, independent of the engines' pipeline shapes —
a block spilled by a 2-stage engine restores into its consolidated
1-stage successor).
"""

from __future__ import annotations

import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.store.kvsegment import KVSegmentStore
from repro.store.store import FetchFlow, FetchSchedule

__all__ = ["KVBlockStore"]

# Payload: ordered (cache_slot_name, k_pages, v_pages) triples; the page
# arrays are (n_attn_periods_total, block_size, n_kv_heads, head_dim),
# concatenated over the pipeline in stage order. Quantized (int8) pools
# append a 4th element: a dict of the per-row scale/zero leaves
# (attention.KV_QUANT_LEAVES), each (n_attn_periods_total, block_size,
# n_kv_heads) f32 — their bytes count toward every spill/restore flow.
Payload = List[Tuple]


def _entry_nbytes(entry) -> int:
    """Exact bytes of one payload entry, auxiliary quant leaves included."""
    n = int(entry[1].nbytes) + int(entry[2].nbytes)
    if len(entry) > 3:
        n += sum(int(np.asarray(a).nbytes) for a in entry[3].values())
    return n


def payload_nbytes(payload: Payload) -> int:
    return sum(_entry_nbytes(e) for e in payload)

HOST_BW = 12e9                       # PCIe class (matches ServerSpec default)


class KVBlockStore:
    """Host + segment KV tiers for spilled page-pool blocks.

    ``put`` (the engine spill hook's sink) inserts at the host tier and
    demotes the host LRU into the segment store past
    ``host_capacity_blocks``. ``take`` moves a block's payload back out
    (single-copy semantics — the block is about to be re-registered in
    an HBM index) and returns the measured ``FetchFlow`` its transfer
    was accounted as. ``now`` is the simulated clock restores are
    admitted at; drivers (FleetFrontend, benches) advance it."""

    def __init__(self, schedule: Optional[FetchSchedule] = None,
                 server_id: str = "local", *,
                 host_capacity_blocks: Optional[int] = None,
                 host_bw: float = HOST_BW,
                 segment_store: Optional[KVSegmentStore] = None,
                 segment_bw: Optional[float] = None):
        self.schedule = schedule or FetchSchedule.single(host_bw, server_id)
        self.server_id = server_id
        self.host_bw = float(host_bw)
        self.host_capacity_blocks = host_capacity_blocks
        self.segments = segment_store if segment_store is not None else \
            KVSegmentStore(**({} if segment_bw is None
                              else {"bandwidth": segment_bw}))
        self.now = 0.0
        self._host: "OrderedDict[bytes, Payload]" = OrderedDict()
        self._host_nbytes: Dict[bytes, int] = {}
        # counters
        self.spills = 0
        self.demotions = 0
        self.restores = 0
        self.spilled_bytes = 0
        self.restored_bytes = 0
        self.restore_flows: List[FetchFlow] = []
        self._fid = 0
        # correctness tracer (analysis/sanitizer.py); None in production
        self.tracer = None

    # ------------------------------------------------------------ queries
    def has(self, h: bytes) -> bool:
        return h in self._host or self.segments.has(h)

    def tier_of(self, h: bytes) -> Optional[str]:
        if h in self._host:
            return "host"
        if self.segments.has(h):
            return "segment"
        return None

    def __len__(self) -> int:
        return len(self._host) + len(self.segments)

    @property
    def host_blocks(self) -> int:
        return len(self._host)

    @property
    def host_bytes(self) -> int:
        return sum(self._host_nbytes.values())

    def bytes_of(self, h: bytes) -> int:
        if h in self._host:
            return self._host_nbytes[h]
        return self.segments.bytes_of(h)

    # ------------------------------------------------------------- tiers
    def put(self, h: bytes, payload: Payload):
        """Spill one evicted block's pages into the host tier (demoting
        the host LRU to the segment store when over budget). Re-spilling
        a hash refreshes its recency; content is identical by
        construction (same chain hash = same computed KV)."""
        if self.tracer is not None:
            self.tracer.on_spill(h, payload)
        if h in self._host:
            self._host.move_to_end(h)
            return
        if self.segments.has(h):          # already demoted: keep one copy
            return
        nbytes = payload_nbytes(payload)
        host = []
        for entry in payload:
            e = (entry[0], np.asarray(entry[1]), np.asarray(entry[2]))
            if len(entry) > 3:
                e += ({l: np.asarray(a) for l, a in entry[3].items()},)
            host.append(e)
        self._host[h] = host
        self._host_nbytes[h] = nbytes
        self.spills += 1
        self.spilled_bytes += nbytes
        cap = self.host_capacity_blocks
        while cap is not None and len(self._host) > cap:
            old_h, old_payload = self._host.popitem(last=False)
            self.segments.put(old_h, old_payload)
            del self._host_nbytes[old_h]
            self.demotions += 1

    def take(self, h: bytes,
             now: Optional[float] = None) -> Tuple[Payload, FetchFlow]:
        """Move a spilled block's payload back toward HBM, accounting the
        transfer as a measured flow capped at the source tier's bandwidth
        on this store's server NIC."""
        now = self.now if now is None else now
        if h in self._host:
            payload = self._host.pop(h)
            nbytes = self._host_nbytes.pop(h)
            cap = self.host_bw
        else:
            payload = self.segments.pop(h)
            nbytes = payload_nbytes(payload)
            cap = self.segments.bandwidth
        flow = self.schedule.transfer(
            self.server_id, f"kvrestore{self._fid}", nbytes,
            now=now, cap=cap)
        self._fid += 1
        self.restores += 1
        self.restored_bytes += nbytes
        self.restore_flows.append(flow)
        if self.tracer is not None:
            self.tracer.on_restore_take(h, payload, nbytes)
        return payload, flow

    def drop(self, h: bytes):
        """Forget a spilled block without restoring it."""
        if self._host.pop(h, None) is not None:
            del self._host_nbytes[h]
        else:
            self.segments.discard(h)

    # ---------------------------------------------------------- modeling
    def restore_rate(self, h: Optional[bytes] = None,
                     now: Optional[float] = None) -> float:
        """Modeled restore bandwidth right now: min(source tier cap,
        Alg. 2 fair share of this server's NIC) — what a restore flow
        admitted at ``now`` would actually get."""
        now = self.now if now is None else now
        if h is None or h in self._host:
            cap = self.host_bw
        elif self.segments.has(h):
            cap = self.segments.bandwidth
        else:
            return 0.0
        share = self.schedule.tracker.node_bandwidth(self.server_id, now)
        if share <= 0.0:                  # Eq. 3 would defer a new flow
            return 0.0
        return min(cap, share)

    def restore_estimate(self, hashes: List[bytes],
                         now: Optional[float] = None) -> float:
        """Modeled seconds to restore these blocks under the current
        contention — the router's restore-vs-reprefill input. inf when
        the NIC cannot admit a flow right now."""
        total = 0.0
        for h in hashes:
            rate = self.restore_rate(h, now)
            if rate <= 0.0:
                return math.inf
            total += self.bytes_of(h) / rate
        return total

    def stats(self) -> dict:
        return {
            "host_blocks": len(self._host),
            "host_bytes": self.host_bytes,
            "segment_blocks": len(self.segments),
            "segment_bytes": self.segments.total_bytes,
            "spills": self.spills,
            "demotions": self.demotions,
            "restores": self.restores,
            "spilled_bytes": self.spilled_bytes,
            "restored_bytes": self.restored_bytes,
        }
