"""KV-aware replica routing.

Given N replicas of one model, each with its own paged prefix cache, the
router decides which replica an incoming prompt should land on. Three
policies behind one interface:

  * ``round_robin`` — replica-oblivious rotation (the baseline the bench
    compares against);
  * ``least_loaded`` — min queued+running, ignoring KV residency;
  * ``kv_affinity`` — scores each replica by the prompt's warm-prefix
    length (via the ``ResidencyIndex``), counts lower-tier *restorable*
    blocks at a discount (they ride the transfer network, not HBM), and
    divides by the replica's load so a long warm prefix on a saturated
    replica does not win forever; when the best replica is *saturated*
    (waiting pool at/over threshold, or a cold start still pending) the
    request overflows to the least-loaded unsaturated replica instead —
    affinity must never add head-of-line latency that outweighs the
    prefill it saves.

Policies see ``ReplicaView`` snapshots (residency match + the engine's
cheap ``stats()`` dict + fleet-provided pending flag) and return a
``RouteDecision`` that records what was known at choice time — the bench
aggregates these for the warm/restorable hit accounting.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

from repro.router.residency import ResidencyIndex

__all__ = ["ReplicaView", "RouteDecision", "RoutingPolicy",
           "RoundRobinPolicy", "LeastLoadedPolicy", "KVAffinityPolicy",
           "Router", "make_routing_policy", "ROUTING_POLICIES"]


@dataclass
class ReplicaView:
    """What a policy knows about one replica at decision time."""
    name: str
    warm_blocks: int
    restorable_blocks: int
    block_size: int
    stats: dict
    pending: bool = False        # cold start in flight (fleet-provided)

    @property
    def warm_tokens(self) -> int:
        return self.warm_blocks * self.block_size

    @property
    def restorable_tokens(self) -> int:
        return self.restorable_blocks * self.block_size

    @property
    def queued(self) -> int:
        return self.stats.get("waiting", 0) + self.stats.get("preempted", 0)

    @property
    def load(self) -> int:
        return self.queued + self.stats.get("running", 0)


@dataclass(frozen=True)
class RouteDecision:
    name: str                    # chosen replica
    policy: str
    warm_blocks: int             # residency of the prompt on the choice
    restorable_blocks: int
    score: float
    overflowed: bool             # saturation pushed us off the best replica


class RoutingPolicy:
    """Pick one ReplicaView. Stateless except where noted."""

    name = "base"

    def choose(self, views: Sequence[ReplicaView]) -> ReplicaView:
        raise NotImplementedError


class RoundRobinPolicy(RoutingPolicy):
    """Rotate over replicas in name order, skipping pending cold starts
    when a ready replica exists. KV-oblivious — the bench baseline."""

    name = "round_robin"

    def __init__(self):
        self._i = 0

    def choose(self, views):
        ordered = sorted(views, key=lambda v: v.name)
        ready = [v for v in ordered if not v.pending] or ordered
        v = ready[self._i % len(ready)]
        self._i += 1
        return v


class LeastLoadedPolicy(RoutingPolicy):
    """Min queued+running (ties by name). KV-oblivious."""

    name = "least_loaded"

    def choose(self, views):
        ready = [v for v in views if not v.pending] or list(views)
        return min(ready, key=lambda v: (v.load, v.name))


class KVAffinityPolicy(RoutingPolicy):
    """Warm-prefix affinity with saturation overflow.

    score = (warm_tokens + restore_frac * restorable_tokens) / (1 + load)

    ``restore_frac`` discounts blocks that would be restored from the
    host/segment tiers — cheaper than re-prefill but not free like an
    HBM hit. A replica is *saturated* when its waiting+preempted pool is
    at/over ``saturation_queue`` or its cold start is still pending; a
    saturated best replica overflows to the least-loaded unsaturated one
    (or stays put if every replica is saturated — then the queue is the
    cost everywhere and affinity still saves the prefill)."""

    name = "kv_affinity"

    def __init__(self, saturation_queue: int = 4,
                 restore_frac: float = 0.5):
        self.saturation_queue = saturation_queue
        self.restore_frac = restore_frac

    def score(self, v: ReplicaView) -> float:
        warm = v.warm_tokens + self.restore_frac * v.restorable_tokens
        return warm / (1.0 + v.load)

    def saturated(self, v: ReplicaView) -> bool:
        return v.pending or v.queued >= self.saturation_queue

    def choose(self, views):
        best = max(views, key=lambda v: (self.score(v), -v.load, v.name))
        if not self.saturated(best):
            return best
        open_ = [v for v in views if not self.saturated(v)]
        if open_:
            return min(open_, key=lambda v: (v.load, v.name))
        return min(views, key=lambda v: (v.load, v.name))


ROUTING_POLICIES = {p.name: p for p in
                    (RoundRobinPolicy, LeastLoadedPolicy, KVAffinityPolicy)}


def make_routing_policy(policy: Union[str, RoutingPolicy],
                        **kw) -> RoutingPolicy:
    if isinstance(policy, RoutingPolicy):
        return policy
    try:
        return ROUTING_POLICIES[policy](**kw)
    except KeyError:
        raise ValueError(f"unknown routing policy {policy!r}: want one of "
                         f"{sorted(ROUTING_POLICIES)} or a RoutingPolicy "
                         "instance") from None


class Router:
    """Replica registry + residency index + policy, for one model.

    Replicas register with their ``ServingEndpoint`` (anything exposing
    ``.engine.block_mgr`` and ``.stats()`` works); the residency index
    attaches to the endpoint's BlockManager, which survives §6.2 engine
    swaps, so a consolidation needs no re-registration. ``route(tokens)``
    snapshots every replica and asks the policy."""

    def __init__(self, policy: Union[str, RoutingPolicy] = "kv_affinity",
                 kv_tier=None, **policy_kw):
        self.policy = make_routing_policy(policy, **policy_kw)
        self.kv_tier = kv_tier
        self.residency = ResidencyIndex(kv_tier=kv_tier)
        self._endpoints: Dict[str, object] = {}
        self._pending: Dict[str, bool] = {}
        self.decisions: List[RouteDecision] = []

    # ------------------------------------------------------- membership
    def register(self, name: str, endpoint):
        self._endpoints[name] = endpoint
        self._pending.setdefault(name, False)
        self.residency.attach(name, endpoint.engine.block_mgr)

    def unregister(self, name: str):
        del self._endpoints[name]
        self._pending.pop(name, None)
        self.residency.detach(name)

    def replicas(self) -> List[str]:
        return list(self._endpoints)

    def endpoint_of(self, name: str):
        return self._endpoints[name]

    def set_pending(self, name: str, pending: bool = True):
        """Fleet signal: this replica's cold start is still in flight
        (counts as saturated / routed around while a ready one exists)."""
        self._pending[name] = pending

    # ---------------------------------------------------------- routing
    def view(self, name: str, tokens: Sequence[int]) -> ReplicaView:
        warm, restorable = self.residency.match(name, tokens)
        return ReplicaView(name, warm, restorable,
                           self.residency.block_size_of(name),
                           self._endpoints[name].stats(),
                           pending=self._pending.get(name, False))

    def route(self, tokens: Sequence[int]) -> RouteDecision:
        if not self._endpoints:
            raise RuntimeError("router has no registered replicas")
        views = [self.view(name, tokens) for name in
                 sorted(self._endpoints)]
        chosen = self.policy.choose(views)
        best_by_affinity = max(
            views, key=lambda v: (v.warm_tokens + v.restorable_tokens,
                                  v.name))
        overflowed = (chosen.name != best_by_affinity.name
                      and best_by_affinity.warm_tokens
                      + best_by_affinity.restorable_tokens > 0)
        d = RouteDecision(chosen.name, self.policy.name,
                          chosen.warm_blocks, chosen.restorable_blocks,
                          getattr(self.policy, "score",
                                  lambda v: 0.0)(chosen),
                          overflowed)
        self.decisions.append(d)
        return d

    def stats(self) -> dict:
        n_over = sum(d.overflowed for d in self.decisions)
        return {
            "policy": self.policy.name,
            "replicas": sorted(self._endpoints),
            "decisions": len(self.decisions),
            "overflows": n_over,
            "warm_blocks_routed": sum(d.warm_blocks for d in
                                      self.decisions),
            "restorable_blocks_routed": sum(d.restorable_blocks
                                            for d in self.decisions),
        }
