"""KV-aware routing subsystem: residency-indexed replica routing with
multi-tier KV spill/restore.

Three layers (ISSUE 7 / ROADMAP item 2):

``residency`` — ``ResidencyIndex``: per-replica mirror of each engine's
                prefix index, kept exact via the BlockManager
                commit/evict notifications; answers "longest warm prefix
                for this token chain per replica".
``router``    — ``Router`` + policies (``kv_affinity``, ``round_robin``,
                ``least_loaded``): scores replicas by warm-prefix length
                discounted by saturation, overflows to least-loaded when
                the preferred replica is saturated.
``kvtier``    — ``KVBlockStore``: HBM → host → segment KV tiers; evicted
                prefix-cache blocks spill instead of vanishing and are
                restored into any same-model replica's page pool on a
                routing hit, the transfer accounted as a measured
                contention-fair flow.
"""

from repro.router.kvtier import KVBlockStore
from repro.router.residency import ResidencyIndex
from repro.router.router import (KVAffinityPolicy, LeastLoadedPolicy,
                                 ReplicaView, RouteDecision,
                                 RoundRobinPolicy, Router, RoutingPolicy,
                                 make_routing_policy)

__all__ = [
    "KVBlockStore", "ResidencyIndex",
    "ReplicaView", "RouteDecision", "RoutingPolicy", "RoundRobinPolicy",
    "LeastLoadedPolicy", "KVAffinityPolicy", "Router",
    "make_routing_policy",
]
