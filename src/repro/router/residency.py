"""Residency index: per-replica map of committed block-chain hashes.

The KV-aware router needs to know, *without touching the engines*, how
much of an incoming prompt each replica already holds in HBM. The
``ResidencyIndex`` keeps one hash set per registered replica and stays
exactly in sync with that replica's ``BlockManager`` through the
commit/evict notifications (serving/kvcache.py): a hash enters the set
when the engine commits the block (or restores it from a lower tier) and
leaves it the moment the LRU evicts it — *before* the block id is
reused, so the index can never claim residency for a page that has been
overwritten.

``match(name, tokens)`` mirrors ``BlockManager.allocate``'s prefix walk
(full blocks only, chain-hashed, continuing past an HBM miss when the
attached KV tier holds the hash) and reports the warm and restorable
block counts — the router's scoring input.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.serving.kvcache import BlockManager, _chain_hash

__all__ = ["ResidencyIndex"]


class ResidencyIndex:
    """Hash-set-per-replica mirror of the engines' prefix indexes."""

    def __init__(self, kv_tier=None):
        self.kv_tier = kv_tier
        self._resident: Dict[str, Set[bytes]] = {}
        # name -> (block_mgr, commit hook, evict hook) for detach
        self._attached: Dict[str, Tuple[BlockManager, object, object]] = {}

    # ------------------------------------------------------- membership
    def attach(self, name: str, block_mgr: BlockManager):
        """Start mirroring a replica's BlockManager. Seeds from the
        current index contents, then stays in sync via the hooks — a
        replica registered mid-flight is immediately accurate."""
        if name in self._attached:
            raise ValueError(f"replica {name!r} already attached")
        resident: Set[bytes] = set(block_mgr.indexed_hashes())
        self._resident[name] = resident

        def on_commit(blk: int, h: bytes):
            resident.add(h)

        def on_evict(blk: int, h: bytes):
            resident.discard(h)

        block_mgr.commit_hooks.append(on_commit)
        block_mgr.evict_hooks.append(on_evict)
        self._attached[name] = (block_mgr, on_commit, on_evict)

    def detach(self, name: str):
        """Stop mirroring (replica scaled to zero / torn down)."""
        bm, on_commit, on_evict = self._attached.pop(name)
        bm.commit_hooks.remove(on_commit)
        bm.evict_hooks.remove(on_evict)
        del self._resident[name]

    def replicas(self) -> List[str]:
        return list(self._resident)

    def resident_hashes(self, name: str) -> Set[bytes]:
        return self._resident[name]

    def block_size_of(self, name: str) -> int:
        return self._attached[name][0].block_size

    # ---------------------------------------------------------- queries
    def chain_hashes(self, name: str,
                     tokens: Sequence[int]) -> List[bytes]:
        """The prompt's full-block chain hashes for this replica's block
        size (the granularity residency is tracked at)."""
        bs = self.block_size_of(name)
        out, h = [], b""
        for i in range(len(tokens) // bs):
            h = _chain_hash(h, tokens[i * bs:(i + 1) * bs])
            out.append(h)
        return out

    def match(self, name: str, tokens: Sequence[int]) -> Tuple[int, int]:
        """(warm_blocks, restorable_blocks) for this prompt on this
        replica: the same walk ``BlockManager.allocate`` will do at
        admission — the chain is followed while each block is either in
        the replica's HBM index (warm) or in the attached KV tier
        (restorable); the first block in neither ends the prefix."""
        resident = self._resident[name]
        warm = restorable = 0
        for h in self.chain_hashes(name, tokens):
            if h in resident:
                warm += 1
            elif self.kv_tier is not None and self.kv_tier.has(h):
                restorable += 1
            else:
                break
        return warm, restorable
