"""GQA attention (self + cross) with contiguous KV cache, RoPE, QKV bias."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.kernels.ref import quantize_kv
from repro.models.common import ParamDef, apply_rope

# Per-row quantization parameters stored alongside int8 page pools, in the
# same cache subtree as k_pages/v_pages so every page-granular operation
# (copy_pages, spill/restore, migration gather) carries them automatically.
KV_QUANT_LEAVES = ("k_scale", "k_zero", "v_scale", "v_zero")


def attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    defs = {
        "w_q": ParamDef((d, hq * hd), ("embed", "heads")),
        "w_k": ParamDef((d, hkv * hd), ("embed", "kv_heads")),
        "w_v": ParamDef((d, hkv * hd), ("embed", "kv_heads")),
        "w_o": ParamDef((hq * hd, d), ("heads", "embed")),
        "norm": ParamDef((d,), ("embed",), init="ones"),
    }
    if cfg.qkv_bias and not cross:
        defs["b_q"] = ParamDef((hq * hd,), ("heads",), init="zeros")
        defs["b_k"] = ParamDef((hkv * hd,), ("kv_heads",), init="zeros")
        defs["b_v"] = ParamDef((hkv * hd,), ("kv_heads",), init="zeros")
    return defs


def _project(cfg, p, x, which: str, n_heads: int):
    w = p[f"w_{which}"]
    y = jnp.einsum("bsd,dh->bsh", x, w.astype(x.dtype))
    if cfg.qkv_bias and f"b_{which}" in p:
        y = y + p[f"b_{which}"].astype(x.dtype)
    b, s, _ = y.shape
    return y.reshape(b, s, n_heads, cfg.head_dim)


def make_kv_cache(cfg: ModelConfig, n_attn_layers: int, batch: int,
                  max_seq: int, dtype) -> dict:
    """Contiguous KV cache for the SPMD serve path (paged cache lives in
    serving/kvcache.py). Layout (L, B, S, Hkv, hd)."""
    shape = (n_attn_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_structs(cfg: ModelConfig, n_attn_layers: int, batch: int,
                     max_seq: int, dtype) -> dict:
    shape = (n_attn_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


KV_CACHE_AXES = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")


def make_paged_kv_cache(cfg: ModelConfig, n_attn_layers: int, n_pages: int,
                        page_size: int, dtype, kv_dtype=None) -> dict:
    """Paged KV pool shared by all sequences: layout (L, N, bs, Hkv, hd);
    sequences address pages through per-request block tables. An int8
    ``kv_dtype`` stores quantized pages plus per-row scale/zero leaves
    (:data:`KV_QUANT_LEAVES`, (L, N, bs, Hkv) f32)."""
    kd = jnp.dtype(kv_dtype) if kv_dtype is not None else jnp.dtype(dtype)
    shape = (n_attn_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    pools = {
        "k_pages": jnp.zeros(shape, kd),
        "v_pages": jnp.zeros(shape, kd),
    }
    if kd == jnp.dtype(jnp.int8):
        for leaf in KV_QUANT_LEAVES:
            pools[leaf] = jnp.zeros(shape[:-1], jnp.float32)
    return pools


def paged_kv_token_bytes(cfg: ModelConfig, kv_dtype=None) -> int:
    """Exact bytes one token row occupies in ONE attention period's page
    pools: the K + V rows plus, for quantized pools, the per-row
    scale/zero leaves. Single source of truth for every KV byte account
    (``BlockManager.bytes_per_token`` → migration_bytes, spill/restore
    flow sizes, roofline KV traffic). ``kv_dtype=None`` means the pools
    hold the compute dtype (``cfg.dtype``) — the pre-quantization
    formula."""
    kd = jnp.dtype(kv_dtype) if kv_dtype is not None \
        else jnp.dtype(cfg.dtype)
    per = 2 * cfg.n_kv_heads * cfg.head_dim * kd.itemsize
    if kd == jnp.dtype(jnp.int8):
        per += len(KV_QUANT_LEAVES) * cfg.n_kv_heads * 4   # f32 scale/zero
    return per


def paged_kv_write(pages, new, block_tables, positions):
    """Scatter new K/V rows into the shared page pool.

    pages (N,bs,Hkv,hd); new (B,S,Hkv,hd); block_tables (B,nb) int32 page
    ids; positions (B,S) absolute token positions (token t of sequence b
    lives at page block_tables[b, t // bs], row t % bs).
    """
    n_pages, bs = pages.shape[0], pages.shape[1]
    page = jnp.take_along_axis(block_tables, positions // bs, axis=1)
    idx = (page * bs + positions % bs).reshape(-1)
    flat = pages.reshape((n_pages * bs,) + pages.shape[2:])
    vals = new.astype(pages.dtype).reshape((-1,) + new.shape[2:])
    return flat.at[idx].set(vals).reshape(pages.shape)


def ragged_kv_write(pages, new, tables, row, pos, valid):
    """Scatter a ragged batch's new K/V rows into the shared page pool.

    pages (N,bs,...); new (T,...trailing dims of pages...); tables (B,nb)
    int32 page ids; row (T,) block-table row per token; pos (T,) absolute
    position per token; valid (T,) bool. Token t lands at page
    ``tables[row[t], pos[t] // bs]``, slot ``pos[t] % bs``; invalid
    (padding) rows are routed to the trash page — the pool's last page,
    which the runner's null-page convention reserves (n_pages =
    n_blocks + 1)."""
    n_pages, bs = pages.shape[0], pages.shape[1]
    posc = jnp.maximum(pos, 0)                        # pad rows: safe index
    page = tables.astype(jnp.int32)[row, posc // bs]  # (T,)
    idx = page * bs + posc % bs
    trash = (n_pages - 1) * bs
    idx = jnp.where(valid, idx, trash)
    flat = pages.reshape((n_pages * bs,) + pages.shape[2:])
    vals = new.astype(pages.dtype)
    return flat.at[idx].set(vals).reshape(pages.shape)


def paged_kv_gather(pages, block_tables, n_tokens: int):
    """Gather rows [0, n_tokens) of each sequence from the page pool into
    a contiguous (B, n_tokens, Hkv, hd) slab — chunked prefill attends
    over this history (pages written by earlier chunks or shared via the
    prefix cache) with ``q_offset``. ``n_tokens`` is static."""
    bs = pages.shape[1]
    pos = jnp.arange(n_tokens)
    flat = pages.reshape((-1,) + pages.shape[2:])

    def one(bt_row):
        return flat[bt_row[pos // bs] * bs + pos % bs]

    return jax.vmap(one)(block_tables)


def self_attention(cfg: ModelConfig, p: dict, x, *, positions,
                   causal: bool = True,
                   kv_cache: Optional[Tuple] = None,
                   decode: bool = False,
                   allow_append: bool = True,
                   block_tables=None,
                   hist_len: int = 0,
                   ragged=None,
                   kv_quant: Optional[dict] = None):
    """x (B,S,d). positions (B,S) absolute positions of the tokens in x.

    Full-sequence mode (train/prefill): attends within x; if kv_cache slices
    (k,v per-layer, (B,Smax,Hkv,hd)) are given they are filled at [0, S).

    Decode mode: S == 1; k/v are scattered into the cache at ``positions``
    and attention runs against the cache with per-sequence lengths.

    When ``block_tables`` (B,nb) is given the kv_cache tuple holds *paged*
    pools (N,bs,Hkv,hd): writes go through :func:`paged_kv_write` and decode
    reads gather pages via the table (ops.paged_decode_attention).

    ``hist_len`` (static, paged prefill only) marks x as a *chunk* whose
    sequence already holds ``hist_len`` KV rows in the pool (earlier
    chunks, or blocks shared through the prefix cache): the chunk's K/V
    are written at ``positions`` and attention runs over the gathered
    rows [0, hist_len + S) with ``q_offset=hist_len`` — bit-identical to
    prefilling the whole sequence at once.

    ``ragged`` = (tables (R,nb), row (T,), valid (T,)) switches to the
    fused ragged-batch path: x is (1, T, d) — a whole mixed step (prefill
    chunks of varying history + decode rows) flattened into one token
    axis, ``positions`` (1, T) giving each token's absolute position
    (-1 = pad). K/V are scattered via :func:`ragged_kv_write` (pads to the
    trash page) and ONE ``ops.ragged_paged_attention`` launch serves the
    whole batch. ``kv_quant`` (the int8 pools' scale/zero leaves) turns on
    quantized writes + fused-dequant loads; ``new_cache`` is then a dict
    of all five pool leaves instead of a (k, v) tuple.
    Returns (out (B,S,d), new_cache or None).
    """
    bsz, seq, _ = x.shape
    q = _project(cfg, p, x, "q", cfg.n_heads)
    k = _project(cfg, p, x, "k", cfg.n_kv_heads)
    v = _project(cfg, p, x, "v", cfg.n_kv_heads)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")

    assert kv_quant is None or ragged is not None, \
        "quantized KV pools are only served by the ragged fused path"
    new_cache = None
    if ragged is not None:
        assert kv_cache is not None and bsz == 1
        tables, row, valid = ragged
        pos1 = positions[0]
        q1, k1, v1 = q[0], k[0], v[0]
        ck, cv = kv_cache
        if kv_quant is not None:
            kq, ks, kz = quantize_kv(k1)
            vq, vs, vz = quantize_kv(v1)
            ck = ragged_kv_write(ck, kq, tables, row, pos1, valid)
            cv = ragged_kv_write(cv, vq, tables, row, pos1, valid)
            nq = {
                "k_scale": ragged_kv_write(kv_quant["k_scale"], ks,
                                           tables, row, pos1, valid),
                "k_zero": ragged_kv_write(kv_quant["k_zero"], kz,
                                          tables, row, pos1, valid),
                "v_scale": ragged_kv_write(kv_quant["v_scale"], vs,
                                           tables, row, pos1, valid),
                "v_zero": ragged_kv_write(kv_quant["v_zero"], vz,
                                          tables, row, pos1, valid),
            }
            new_cache = {"k_pages": ck, "v_pages": cv, **nq}
            out1 = ops.ragged_paged_attention(q1, ck, cv, tables, row,
                                              pos1, kv_quant=nq)
        else:
            ck = ragged_kv_write(ck, k1, tables, row, pos1, valid)
            cv = ragged_kv_write(cv, v1, tables, row, pos1, valid)
            new_cache = (ck, cv)
            out1 = ops.ragged_paged_attention(q1, ck, cv, tables, row,
                                              pos1)
        out = out1[None].astype(x.dtype)
    elif not decode:
        assert hist_len == 0 or block_tables is not None, \
            "chunked prefill (hist_len > 0) needs the paged layout"
        if kv_cache is not None:
            ck, cv = kv_cache
            if block_tables is not None:
                ck = paged_kv_write(ck, k, block_tables, positions)
                cv = paged_kv_write(cv, v, block_tables, positions)
            else:
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                                  (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                                  (0, 0, 0, 0))
            new_cache = (ck, cv)
        if hist_len:
            # chunk continuation: attend over history + chunk from the
            # pool (the chunk's own K/V round-trip through the pages —
            # identity, the pool dtype is the compute dtype)
            total = hist_len + seq
            k_att = paged_kv_gather(ck, block_tables, total)
            v_att = paged_kv_gather(cv, block_tables, total)
            out = ops.flash_attention(q, k_att, v_att, causal=causal,
                                      q_offset=hist_len)
        else:
            out = ops.flash_attention(q, k, v, causal=causal, q_offset=0)
    else:
        assert kv_cache is not None and seq == 1
        ck, cv = kv_cache
        if block_tables is not None:
            ck = paged_kv_write(ck, k, block_tables, positions)
            cv = paged_kv_write(cv, v, block_tables, positions)
            new_cache = (ck, cv)
            kv_len = positions[:, 0] + 1
            out = ops.paged_decode_attention(q, ck, cv, block_tables, kv_len)
        elif ops.decode_mode() == "append" and allow_append:
            # §Perf it.5: attend over the old cache [0, pos) and combine the
            # new token in closed form; the cache write happens once,
            # outside the layer scan (run_blocks), so the full cache is not
            # threaded through the loop carries.
            from repro.kernels import ref as _ref
            out_c, m_c, l_c = _ref.decode_attention_with_stats(
                q, ck, cv, positions[:, 0])
            scale = 1.0 / (cfg.head_dim ** 0.5)
            rep = cfg.n_heads // cfg.n_kv_heads
            k_exp = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
            v_exp = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
            s_n = jnp.einsum("bqhd,bqhd->bh", q.astype(jnp.float32),
                             k_exp) * scale                # (B,Hq)
            m_new = jnp.maximum(m_c, s_n)
            alpha = jnp.exp(m_c - m_new)
            beta = jnp.exp(s_n - m_new)
            num = out_c * alpha[:, None, :, None] \
                + beta[:, None, :, None] * v_exp
            den = l_c * alpha + beta
            out = (num / den[:, None, :, None]).astype(q.dtype)
            new_cache = ("append", k, v)
        else:
            def put(cache, new):
                def upd(c_b, n_b, pos):
                    return jax.lax.dynamic_update_slice(
                        c_b, n_b.astype(c_b.dtype), (pos, 0, 0))
                return jax.vmap(upd)(cache, new, positions[:, 0])

            ck = put(ck, k)
            cv = put(cv, v)
            ck = constrain(ck, *KV_CACHE_AXES[1:])
            cv = constrain(cv, *KV_CACHE_AXES[1:])
            new_cache = (ck, cv)
            kv_len = positions[:, 0] + 1
            out = ops.decode_attention(q, ck, cv, kv_len)

    out = constrain(out, "batch", "seq", "heads", "head_dim")
    b, s, hq, hd = out.shape
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, hq * hd),
                   p["w_o"].astype(x.dtype))
    # seq-sharded output: turns the TP partial-sum all-reduce into a
    # reduce-scatter when sequence parallelism is active (§Perf it.2)
    return constrain(y, "batch", "act_seq", "embed"), new_cache


def cross_attention(cfg: ModelConfig, p: dict, x, memory=None,
                    mem_kv: Optional[Tuple] = None):
    """Encoder-decoder cross attention. ``memory`` (B,Sm,d) or precomputed
    ``mem_kv`` (k,v) (B,Sm,Hkv,hd) — the serve path precomputes them once."""
    if mem_kv is None:
        k = _project(cfg, p, memory, "k", cfg.n_kv_heads)
        v = _project(cfg, p, memory, "v", cfg.n_kv_heads)
    else:
        k, v = mem_kv
    q = _project(cfg, p, x, "q", cfg.n_heads)
    out = ops.flash_attention(q, k, v, causal=False)
    b, s, hq, hd = out.shape
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, hq * hd),
                   p["w_o"].astype(x.dtype))
    return constrain(y, "batch", "seq", "embed")


def precompute_cross_kv(cfg: ModelConfig, p: dict, memory):
    k = _project(cfg, p, memory, "k", cfg.n_kv_heads)
    v = _project(cfg, p, memory, "v", cfg.n_kv_heads)
    return k, v
