"""Shared model building blocks: ParamDef trees, RMSNorm, RoPE, init."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import resolve


# ---------------------------------------------------------------------------
# Parameter definition trees.  A model is described once as a pytree of
# ParamDef; init / sharding-spec / ShapeDtypeStruct trees derive from it.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names, one per dim
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float = 1.0                    # extra init scale (e.g. 1/sqrt(2L))

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def map_defs(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=_is_def)


def init_params(defs, key, dtype):
    """Random-init a ParamDef tree into real arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            a = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(fan_in, 1))
            a = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def param_specs(defs):
    """PartitionSpec tree (resolved under the active mesh rules)."""
    return map_defs(lambda d: resolve(d.axes), defs)


def param_structs(defs, dtype):
    return map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)


def param_bytes(defs, bytes_per_param=2) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(math.prod(d.shape) for d in leaves) * bytes_per_param


def stack_defs(defs, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking dim (e.g. periods) to every ParamDef in the tree."""
    return map_defs(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale),
        defs,
    )


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float):
    """bf16-safe RMSNorm: only the variance reduction runs in fp32; the
    (B,S,d)-sized tensors stay in the compute dtype so backward cotangents
    (and the TP all-reduces GSPMD places inside them) are bf16, not fp32 —
    this halves per-layer collective volume (EXPERIMENTS.md §Perf it.1)."""
    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv * w.astype(dt)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """logits (..., V) fp32-cast CE with optional z-loss; labels < 0 masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
