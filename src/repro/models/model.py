"""Model facade: one object per architecture with train / prefill / decode
entry points, ParamDef trees (init, sharding specs, ShapeDtypeStructs), KV
caches, and the stage-slicing API used by pipeline-parallel cold starts."""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.common import (ParamDef, cross_entropy, init_params,
                                 map_defs, param_bytes, param_specs,
                                 param_structs)

AUX_LOSS_WEIGHT = 0.01
Z_LOSS = 1e-4


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    @property
    def defs(self) -> dict:
        if self.cfg.is_encdec:
            return encdec.encdec_defs(self.cfg)
        return transformer.lm_defs(self.cfg)

    def init(self, key):
        return init_params(self.defs, key, _dtype(self.cfg))

    def specs(self):
        return param_specs(self.defs)

    def structs(self):
        return param_structs(self.defs, _dtype(self.cfg))

    def bytes(self) -> int:
        return param_bytes(self.defs, jnp.dtype(self.cfg.dtype).itemsize)

    # ------------------------------------------------------------- inputs
    def input_structs(self, batch: int, seq: int) -> dict:
        cfg = self.cfg
        out = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_image_tokens, cfg.d_model), _dtype(cfg))
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_audio_frames, cfg.d_model), _dtype(cfg))
        return out

    def dummy_inputs(self, key, batch: int, seq: int) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab)}
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.random.normal(
                k2, (batch, cfg.n_image_tokens, cfg.d_model), _dtype(cfg)) * 0.02
        if cfg.is_encdec:
            out["frames"] = jax.random.normal(
                k2, (batch, cfg.n_audio_frames, cfg.d_model), _dtype(cfg)) * 0.02
        return out

    # --------------------------------------------------------------- train
    def loss(self, params, batch: dict, *, remat: str = "none"):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        if cfg.is_encdec:
            memory = encdec.encode(cfg, params, batch["frames"])
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            h, _ = encdec.decoder(cfg, params, tokens, positions,
                                  memory=memory, remat=remat,
                                  dtype=_dtype(cfg))
            logits = encdec.head(cfg, params, h)
            labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)),
                             constant_values=-1)
            ce = cross_entropy(logits, labels, Z_LOSS)
            return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

        prefix = batch.get("patch_embeds")
        plen = prefix.shape[1] if prefix is not None else 0
        total = plen + s
        positions = jnp.broadcast_to(jnp.arange(total)[None], (b, total))
        x = transformer.embed(cfg, params, tokens, positions,
                              prefix_embeds=prefix, dtype=_dtype(cfg))
        x, _, aux = transformer.run_blocks(cfg, params["blocks"], x,
                                           positions, remat=remat)
        logits = transformer.head(cfg, params, x)
        logits = logits[:, plen:]
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
        ce = cross_entropy(logits, labels, Z_LOSS)
        loss = ce + AUX_LOSS_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int, as_structs: bool = False):
        cfg = self.cfg
        dt = _dtype(cfg)
        if cfg.is_encdec:
            return {
                "self": encdec.init_self_cache(cfg, batch, max_seq, dt,
                                               as_structs),
                "cross": (encdec.cross_kv_structs(cfg, batch, dt)
                          if as_structs else None),
            }
        return transformer.init_cache(cfg, batch, max_seq, dt, as_structs)

    def cache_axes(self):
        cfg = self.cfg
        if cfg.is_encdec:
            a = ("layers",) + ("batch", "kv_seq", "kv_heads", "head_dim")
            c = ("layers", "batch", "seq", "kv_heads", "head_dim")
            return {"self": {"k": a, "v": a}, "cross": {"k": c, "v": c}}
        return transformer.cache_axes(cfg)

    def prefill(self, params, batch: dict, max_seq: int, *,
                remat: str = "none"):
        """Full-prompt pass; returns (last-token logits (B,V), cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        if cfg.is_encdec:
            memory = encdec.encode(cfg, params, batch["frames"])
            cross_kv = encdec.precompute_cross_kv(cfg, params, memory)
            cache = encdec.init_self_cache(cfg, b, max_seq, _dtype(cfg))
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            h, self_cache = encdec.decoder(cfg, params, tokens, positions,
                                           cross_kv=cross_kv,
                                           self_cache=cache, dtype=_dtype(cfg))
            logits = encdec.head(cfg, params, h[:, -1:])
            return logits[:, 0], {"self": self_cache, "cross": cross_kv}

        prefix = batch.get("patch_embeds")
        plen = prefix.shape[1] if prefix is not None else 0
        total = plen + s
        positions = jnp.broadcast_to(jnp.arange(total)[None], (b, total))
        x = transformer.embed(cfg, params, tokens, positions,
                              prefix_embeds=prefix, dtype=_dtype(cfg))
        cache = transformer.init_cache(cfg, b, max_seq, _dtype(cfg))
        x, cache, _ = transformer.run_blocks(cfg, params["blocks"], x,
                                             positions, cache=cache,
                                             remat=remat)
        logits = transformer.head(cfg, params, x[:, -1:])
        return logits[:, 0], cache

    def decode_step(self, params, cache, tokens, positions):
        """One decode step. tokens (B,1) int32; positions (B,1) — the cache
        slot each new token is written to (attends to [0, pos])."""
        cfg = self.cfg
        if cfg.is_encdec:
            h, self_cache = encdec.decoder(cfg, params, tokens, positions,
                                           cross_kv=cache["cross"],
                                           self_cache=cache["self"],
                                           decode=True, dtype=_dtype(cfg))
            logits = encdec.head(cfg, params, h)
            return logits[:, 0], {"self": self_cache, "cross": cache["cross"]}
        x = transformer.embed(cfg, params, tokens, positions,
                              dtype=_dtype(cfg))
        x, cache, _ = transformer.run_blocks(cfg, params["blocks"], x,
                                             positions, cache=cache,
                                             decode=True)
        logits = transformer.head(cfg, params, x)
        return logits[:, 0], cache

    # ------------------------------------------ pipeline stages (the paper)
    def stage_ranges(self, n_stages: int):
        return transformer.stage_period_ranges(self.cfg.n_periods, n_stages)

    def stage_defs(self, n_stages: int, stage: int) -> dict:
        """ParamDef subtree a stage must fetch (drives byte accounting)."""
        full = self.defs
        p0, p1 = self.stage_ranges(n_stages)[stage]
        out = {"blocks": map_defs(
            lambda d: ParamDef((p1 - p0,) + d.shape[1:], d.axes, d.init,
                               d.scale),
            full["blocks"])}
        if stage == 0:
            out["embed"] = full["embed"]
            if self.cfg.is_encdec:
                out["encoder"] = full["encoder"]
                out["enc_final_norm"] = full["enc_final_norm"]
        if stage == n_stages - 1:
            out["final_norm"] = full["final_norm"]
            if "lm_head" in full:
                out["lm_head"] = full["lm_head"]
        return out

    def stage_bytes(self, n_stages: int, stage: int) -> int:
        return param_bytes(self.stage_defs(n_stages, stage),
                           jnp.dtype(self.cfg.dtype).itemsize)

    def slice_stage_params(self, params, n_stages: int, stage: int) -> dict:
        """Materialize a stage's param slice from full params."""
        p0, p1 = self.stage_ranges(n_stages)[stage]
        out = {"blocks": transformer.slice_blocks(params["blocks"], p0, p1)}
        if stage == 0:
            out["embed"] = params["embed"]
            if self.cfg.is_encdec:
                out["encoder"] = params["encoder"]
                out["enc_final_norm"] = params["enc_final_norm"]
        if stage == n_stages - 1:
            out["final_norm"] = params["final_norm"]
            if "lm_head" in params:
                out["lm_head"] = params["lm_head"]
        return out


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
