"""Whisper-style encoder-decoder backbone. The conv/audio frontend is a STUB:
inputs are precomputed frame embeddings (B, n_frames, d_model)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import ParamDef, rmsnorm, stack_defs


def encdec_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    enc_block = {
        "attn": attn.attn_defs(cfg),
        "mlp": mlp_mod.dense_mlp_defs(cfg),
    }
    dec_block = {
        "self": attn.attn_defs(cfg),
        "cross": attn.attn_defs(cfg, cross=True),
        "cross_norm": ParamDef((d,), ("embed",), init="ones"),
        "mlp": mlp_mod.dense_mlp_defs(cfg),
    }
    return {
        "embed": {
            "tok": ParamDef((cfg.padded_vocab, d), ("vocab", "embed")),
            "pos": ParamDef((cfg.max_position, d), (None, "embed")),
            "enc_pos": ParamDef((cfg.n_audio_frames, d), (None, "embed")),
        },
        "encoder": stack_defs(enc_block, cfg.encoder_layers, "layers"),
        "enc_final_norm": ParamDef((d,), ("embed",), init="ones"),
        "blocks": stack_defs(dec_block, cfg.n_layers, "layers"),
        "final_norm": ParamDef((d,), ("embed",), init="ones"),
        "lm_head": ParamDef((d, cfg.padded_vocab), ("embed", "vocab")),
    }


def encode(cfg: ModelConfig, params: dict, frames):
    """frames (B,F,d) stub embeddings -> encoder memory (B,F,d)."""
    b, f, d = frames.shape
    x = frames + params["embed"]["enc_pos"][:f].astype(frames.dtype)
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

    def step(h, pslice):
        xin = rmsnorm(h, pslice["attn"]["norm"], cfg.norm_eps)
        y, _ = attn.self_attention(cfg, pslice["attn"], xin,
                                   positions=positions, causal=False)
        h = h + y
        xin = rmsnorm(h, pslice["mlp"]["norm"], cfg.norm_eps)
        h = h + mlp_mod.dense_mlp(pslice["mlp"], xin)
        return h, None

    x, _ = jax.lax.scan(step, x, params["encoder"])
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def precompute_cross_kv(cfg: ModelConfig, params: dict, memory):
    """Per-decoder-layer cross K/V: (L,B,F,Hkv,hd) each."""
    def one(pslice, _):
        k, v = attn.precompute_cross_kv(cfg, pslice["cross"], memory)
        return None, (k, v)

    _, (k, v) = jax.lax.scan(lambda c, p: one(p, c), None, params["blocks"])
    return {"k": k, "v": v}


def cross_kv_structs(cfg: ModelConfig, batch: int, dtype):
    shp = (cfg.n_layers, batch, cfg.n_audio_frames, cfg.n_kv_heads,
           cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}


def decoder(cfg: ModelConfig, params: dict, tokens, positions, *,
            memory=None, cross_kv: Optional[dict] = None,
            self_cache: Optional[dict] = None, decode: bool = False,
            remat: str = "none", dtype=None):
    """Decoder stack. Either ``memory`` (train: cross K/V computed inline) or
    precomputed ``cross_kv`` (serve path). Returns (hidden, new_self_cache)."""
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if dtype is not None:
        x = x.astype(dtype)
    x = x + jnp.take(params["embed"]["pos"], positions, axis=0).astype(x.dtype)
    x = constrain(x, "batch", "seq", "embed")

    if cross_kv is None:
        assert memory is not None
        cross_kv = precompute_cross_kv(cfg, params, memory)

    def step(h, xs):
        pslice, ckv_k, ckv_v, cslice = xs
        xin = rmsnorm(h, pslice["self"]["norm"], cfg.norm_eps)
        kvc = (cslice["k"], cslice["v"]) if cslice is not None else None
        y, nc = attn.self_attention(cfg, pslice["self"], xin,
                                    positions=positions, causal=True,
                                    kv_cache=kvc, decode=decode,
                                    allow_append=False)
        h = constrain(h + y, "batch", "act_seq", "embed")
        xin = rmsnorm(h, pslice["cross_norm"], cfg.norm_eps)
        y = attn.cross_attention(cfg, pslice["cross"], xin,
                                 mem_kv=(ckv_k, ckv_v))
        h = h + y
        xin = rmsnorm(h, pslice["mlp"]["norm"], cfg.norm_eps)
        h = constrain(h + mlp_mod.dense_mlp(pslice["mlp"], xin),
                      "batch", "act_seq", "embed")
        new_c = {"k": nc[0], "v": nc[1]} if nc is not None else None
        return h, new_c

    if remat in ("full", "dots"):
        pol = (None if remat == "full" else
               jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        step = jax.checkpoint(step, prevent_cse=False, policy=pol)

    xs = (params["blocks"], cross_kv["k"], cross_kv["v"], self_cache)
    x, new_cache = jax.lax.scan(step, x, xs)
    return x, new_cache


def head(cfg: ModelConfig, params: dict, x):
    xn = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", xn, params["lm_head"].astype(xn.dtype))
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return constrain(logits, "batch", "seq", "vocab")


def init_self_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype,
                    as_structs: bool = False):
    shp = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    if as_structs:
        return {"k": jax.ShapeDtypeStruct(shp, dtype),
                "v": jax.ShapeDtypeStruct(shp, dtype)}
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
