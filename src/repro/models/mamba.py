"""Mamba-1 selective-SSM mixer (Jamba's recurrent layer).

Full-sequence mode uses a two-level chunked scan: the outer ``lax.scan``
carries the SSM state across chunks (checkpointed boundaries), the inner
per-step scan is wrapped in ``jax.checkpoint`` so training memory is
O(S/chunk * B*d_in*n) instead of O(S * B*d_in*n).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef, silu


def _dims(cfg: ModelConfig):
    d_in = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, d_in // 16)
    return d_in, cfg.mamba_d_state, cfg.mamba_d_conv, dt_rank


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, n, d_conv, dt_rank = _dims(cfg)
    return {
        "in_proj": ParamDef((d, 2 * d_in), ("embed", "ffn")),
        "conv_w": ParamDef((d_conv, d_in), ("conv", "ffn")),
        "conv_b": ParamDef((d_in,), ("ffn",), init="zeros"),
        "x_proj": ParamDef((d_in, dt_rank + 2 * n), ("ffn", None)),
        "dt_w": ParamDef((dt_rank, d_in), ("dt_rank", "ffn")),
        "dt_b": ParamDef((d_in,), ("ffn",), init="zeros"),
        "A_log": ParamDef((d_in, n), ("ffn", "state"), init="ones"),
        "D": ParamDef((d_in,), ("ffn",), init="ones"),
        "out_proj": ParamDef((d_in, d), ("ffn", "embed")),
        "norm": ParamDef((d,), ("embed",), init="ones"),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_in, n, d_conv, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, n), jnp.float32),
    }


MAMBA_CACHE_AXES = {
    "conv": ("batch", None, "ffn"),
    "h": ("batch", "ffn", "state"),
}


def _causal_conv(x, conv_w, conv_b, history=None):
    """x (B,S,d_in); history (B,d_conv-1,d_in) prepended (zeros if None)."""
    d_conv = conv_w.shape[0]
    b, s, d_in = x.shape
    if history is None:
        history = jnp.zeros((b, d_conv - 1, d_in), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(d_conv):
        y = y + conv_w[i].astype(x.dtype) * jax.lax.dynamic_slice_in_dim(
            xp, i, s, axis=1)
    return y + conv_b.astype(x.dtype), xp[:, -(d_conv - 1):, :]


def _ssm_inputs(cfg, p, xc):
    """xc (B,S,d_in) post-conv activations -> (dt, B, C, A)."""
    d_in, n, _, dt_rank = _dims(cfg)
    dbc = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt_r = dbc[..., :dt_rank]
    Bm = dbc[..., dt_rank:dt_rank + n].astype(jnp.float32)
    Cm = dbc[..., dt_rank + n:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, p["dt_w"].astype(xc.dtype))
        .astype(jnp.float32) + p["dt_b"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (d_in, n)
    return dt, Bm, Cm, A


def _scan_chunk(A, h0, dt, Bm, Cm, u):
    """Sequential scan inside one chunk. dt,u (B,c,d_in); Bm,Cm (B,c,n)."""
    def step(h, xs):
        dt_t, b_t, c_t, u_t = xs
        dA = jnp.exp(dt_t[..., None] * A[None])            # (B,d_in,n)
        dBu = (dt_t * u_t)[..., None] * b_t[:, None, :]    # (B,d_in,n)
        h_new = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h_new, c_t)
        return h_new, y

    xs = (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), u.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return h, ys.transpose(1, 0, 2)                        # (B,c,d_in)


def mamba_mixer(cfg: ModelConfig, p: dict, x, *, cache: Optional[dict] = None,
                decode: bool = False, chunk: int = 64) -> Tuple:
    """x (B,S,d). Returns (y (B,S,d), new_cache)."""
    b, s, d = x.shape
    d_in, n, d_conv, _ = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = constrain(x1, "batch", "seq", "ffn")

    history = cache["conv"] if cache is not None else None
    xc, new_hist = _causal_conv(x1, p["conv_w"], p["conv_b"], history)
    xc = silu(xc)

    dt, Bm, Cm, A = _ssm_inputs(cfg, p, xc)
    u = xc.astype(jnp.float32)
    h0 = (cache["h"] if cache is not None
          else jnp.zeros((b, d_in, n), jnp.float32))

    if decode or s == 1:
        h, ys = _scan_chunk(A, h0, dt, Bm, Cm, u)
    else:
        c = min(chunk, s)
        if s % c:
            pad = c - s % c
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        nc = dt.shape[1] // c

        def outer(h, xs):
            dt_c, b_c, c_c, u_c = xs
            h, ys = jax.checkpoint(
                lambda h_, args: _scan_chunk(A, h_, *args))(h, (dt_c, b_c, c_c, u_c))
            return h, ys

        resh = lambda a: a.reshape(b, nc, c, a.shape[-1]).transpose(1, 0, 2, 3)
        h, ys = jax.lax.scan(outer, h0, (resh(dt), resh(Bm), resh(Cm), resh(u)))
        ys = ys.transpose(1, 0, 2, 3).reshape(b, nc * c, d_in)[:, :s]

    y = ys.astype(x.dtype) + p["D"].astype(x.dtype) * xc
    y = y * silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    new_cache = {"conv": new_hist.astype(x.dtype), "h": h}
    return constrain(out, "batch", "seq", "embed"), new_cache
