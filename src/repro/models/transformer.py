"""Unified decoder LM over a repeated *period* of heterogeneous layers.

A period is ``cfg.mixer_pattern`` (attn/mamba/rwkv slots) zipped with the MoE
cadence ``cfg.mlp_pattern``; the full network is ``n_periods`` repetitions,
executed with one ``lax.scan`` over stacked per-period params (small HLO,
fast multi-pod compiles).  Pipeline-parallel cold starts slice the stacked
axis — stage i owns periods [p0, p1) — via ``slice_blocks``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import ParamDef, rmsnorm, stack_defs


def _period_plan(cfg: ModelConfig):
    return [(mix, cfg.mlp_pattern[i % len(cfg.mlp_pattern)])
            for i, mix in enumerate(cfg.mixer_pattern)]


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig) -> dict:
    defs = {}
    for i, (mix, mlp) in enumerate(_period_plan(cfg)):
        slot = {}
        if mix == "attn":
            slot["mixer"] = attn.attn_defs(cfg)
        elif mix == "mamba":
            slot["mixer"] = mamba_mod.mamba_defs(cfg)
        elif mix == "rwkv":
            slot["mixer"] = rwkv_mod.rwkv_defs(cfg)
        else:
            raise ValueError(mix)
        if mlp == "dense":
            slot["mlp"] = mlp_mod.dense_mlp_defs(cfg)
        elif mlp == "moe":
            slot["mlp"] = mlp_mod.moe_defs(cfg)
        defs[f"slot{i:02d}"] = slot
    return defs


def lm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    defs = {
        "embed": {"tok": ParamDef((cfg.padded_vocab, d), ("vocab", "embed"))},
        "blocks": stack_defs(block_defs(cfg), cfg.n_periods, "layers"),
        "final_norm": ParamDef((d,), ("embed",), init="ones"),
    }
    if cfg.pos_embed == "learned":
        defs["embed"]["pos"] = ParamDef((cfg.max_position, d), (None, "embed"))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.padded_vocab), ("embed", "vocab"))
    return defs


# ---------------------------------------------------------------------------
# Caches (stacked over periods on axis 0 for the scan)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype,
               as_structs: bool = False, n_periods: Optional[int] = None,
               paged: bool = False, n_pages: Optional[int] = None,
               page_size: Optional[int] = None, kv_dtype=None):
    """Stacked per-period cache. ``paged=True`` stores attention KV as a
    shared page pool (np, N, bs, Hkv, hd) addressed via block tables
    (serving/kvcache.py) instead of slot-contiguous (np, B, S, Hkv, hd);
    recurrent mixer states stay slot-indexed either way. ``kv_dtype``
    (paged only) overrides the pool storage dtype; int8 adds per-row
    scale/zero leaves (attention.KV_QUANT_LEAVES, f32)."""
    np_ = n_periods if n_periods is not None else cfg.n_periods
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if as_structs \
        else (lambda s, dt: jnp.zeros(s, dt))
    cache = {}
    d_in = cfg.mamba_expand * cfg.d_model
    for i, (mix, _) in enumerate(_period_plan(cfg)):
        slot = f"slot{i:02d}"
        if mix == "attn":
            if paged:
                assert n_pages is not None and page_size is not None
                kd = jnp.dtype(kv_dtype) if kv_dtype is not None \
                    else jnp.dtype(dtype)
                shp = (np_, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
                cache[slot] = {"k_pages": mk(shp, kd),
                               "v_pages": mk(shp, kd)}
                if kd == jnp.dtype(jnp.int8):
                    for leaf in attn.KV_QUANT_LEAVES:
                        cache[slot][leaf] = mk(shp[:-1], jnp.float32)
                continue
            shp = (np_, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
            cache[slot] = {"k": mk(shp, dtype), "v": mk(shp, dtype)}
        elif mix == "mamba":
            cache[slot] = {
                "conv": mk((np_, batch, cfg.mamba_d_conv - 1, d_in), dtype),
                "h": mk((np_, batch, d_in, cfg.mamba_d_state), jnp.float32),
            }
        elif mix == "rwkv":
            cache[slot] = {
                "shift": mk((np_, batch, 1, cfg.d_model), dtype),
                "wkv": mk((np_, batch, cfg.n_heads, cfg.head_dim,
                           cfg.head_dim), jnp.float32),
            }
    return cache


def cache_axes(cfg: ModelConfig) -> dict:
    axes = {}
    for i, (mix, _) in enumerate(_period_plan(cfg)):
        slot = f"slot{i:02d}"
        if mix == "attn":
            a = ("layers",) + attn.KV_CACHE_AXES[1:]
            axes[slot] = {"k": a, "v": a}
        elif mix == "mamba":
            axes[slot] = {k: ("layers",) + v
                          for k, v in mamba_mod.MAMBA_CACHE_AXES.items()}
        elif mix == "rwkv":
            axes[slot] = {k: ("layers",) + v
                          for k, v in rwkv_mod.RWKV_CACHE_AXES.items()}
    return axes


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def embed(cfg: ModelConfig, params: dict, tokens, positions,
          prefix_embeds=None, dtype=None):
    """tokens (B,S) -> x (B, [n_img+]S, d). prefix_embeds (B,P,d) optional."""
    tok_w = params["embed"]["tok"]
    x = jnp.take(tok_w, tokens, axis=0)
    if dtype is not None:
        x = x.astype(dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.pos_embed == "learned":
        pos_w = params["embed"]["pos"]
        x = x + jnp.take(pos_w, positions, axis=0).astype(x.dtype)
    return constrain(x, "batch", "seq", "embed")


def head(cfg: ModelConfig, params: dict, x):
    xn = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = (params["embed"]["tok"].T if cfg.tie_embeddings
         else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", xn, w.astype(xn.dtype))
    if cfg.padded_vocab != cfg.vocab:      # mask padded vocab entries
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return constrain(logits, "batch", "seq", "vocab")


def _period_step(cfg: ModelConfig, pslice: dict, cslice, x, positions,
                 decode: bool, causal: bool, block_tables=None,
                 hist_len: int = 0, ragged=None):
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for i, (mix, mlp) in enumerate(_period_plan(cfg)):
        slot = f"slot{i:02d}"
        sp = pslice[slot]
        c = cslice.get(slot) if cslice is not None else None
        xin = rmsnorm(x, sp["mixer"]["norm"], cfg.norm_eps)
        if mix == "attn":
            paged = c is not None and "k_pages" in c
            if paged:
                kvc = (c["k_pages"], c["v_pages"])
            else:
                kvc = (c["k"], c["v"]) if c is not None else None
            kvq = ({leaf: c[leaf] for leaf in attn.KV_QUANT_LEAVES}
                   if paged and "k_scale" in c else None)
            y, nc = attn.self_attention(cfg, sp["mixer"], xin,
                                        positions=positions, causal=causal,
                                        kv_cache=kvc, decode=decode,
                                        block_tables=(block_tables if paged
                                                      else None),
                                        hist_len=hist_len if paged else 0,
                                        ragged=ragged, kv_quant=kvq)
            if nc is not None:
                if isinstance(nc, dict):
                    # ragged int8 path: all five pool leaves
                    new_cache[slot] = nc
                elif isinstance(nc, tuple) and nc[0] == "append":
                    # §Perf it.5: only the new token's K/V leave the scan;
                    # run_blocks writes them into the cache once, after.
                    new_cache[slot] = {"k_new": nc[1], "v_new": nc[2]}
                elif paged:
                    new_cache[slot] = {"k_pages": nc[0], "v_pages": nc[1]}
                else:
                    new_cache[slot] = {"k": nc[0], "v": nc[1]}
            elif c is not None:
                new_cache[slot] = c
        elif mix == "mamba":
            y, nc = mamba_mod.mamba_mixer(cfg, sp["mixer"], xin, cache=c,
                                          decode=decode)
            if cslice is not None:
                new_cache[slot] = nc
        else:  # rwkv
            y, nc = rwkv_mod.rwkv_mixer(cfg, sp["mixer"], xin, cache=c,
                                        decode=decode)
            if cslice is not None:
                new_cache[slot] = nc
        x = constrain(x + y, "batch", "act_seq", "embed")
        if mlp is not None and "mlp" in sp:
            xin = rmsnorm(x, sp["mlp"]["norm"], cfg.norm_eps)
            if mlp == "dense":
                y = mlp_mod.dense_mlp(sp["mlp"], xin)
            else:
                y, a = mlp_mod.moe_mlp(cfg, sp["mlp"], xin)
                aux = aux + a
            x = constrain(x + y, "batch", "act_seq", "embed")
    return x, (new_cache if cslice is not None else None), aux


def run_blocks(cfg: ModelConfig, blocks: dict, x, positions, *,
               cache: Optional[dict] = None, decode: bool = False,
               causal: bool = True, remat: str = "none",
               block_tables=None, hist_len: int = 0, ragged=None):
    """Scan the stacked periods. ``blocks``/``cache`` leading dim = periods
    (possibly a stage's slice). ``block_tables`` (B,nb) addresses paged attn
    pools (shared across periods — the page id axis is per-period).
    ``hist_len`` (static) marks x as a prefill *chunk* with that many KV
    rows already in the paged pools (see attention.self_attention).
    ``ragged`` = (tables, row, valid) routes attention through the fused
    ragged-batch kernel — x is (1, T, d), positions (1, T) with -1 pads.
    Returns (x, new_cache, aux_sum)."""

    def step(carry, xs):
        h, aux = carry
        pslice, cslice = xs
        h, new_c, a = _period_step(cfg, pslice, cslice, h, positions,
                                   decode, causal, block_tables=block_tables,
                                   hist_len=hist_len, ragged=ragged)
        return (h, aux + a), new_c

    if remat == "full":
        step = jax.checkpoint(step, prevent_cse=False)
    elif remat == "dots":
        step = jax.checkpoint(
            step, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), new_cache = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                       (blocks, cache))

    if decode and cache is not None and new_cache:
        # append-mode post-pass: one batched write of every layer's new
        # token into the (donated) cache — the full cache never rode the
        # scan carries (§Perf it.5)
        pos = positions[:, 0]

        def write(c, n):
            def per_period(cp, np_):
                def per_batch(cb, nb, p):
                    return jax.lax.dynamic_update_slice(
                        cb, nb.astype(cb.dtype), (p, 0, 0))
                return jax.vmap(per_batch)(cp, np_, pos)
            return jax.vmap(per_period)(c, n)

        for slot, val in list(new_cache.items()):
            if isinstance(val, dict) and "k_new" in val:
                new_cache[slot] = {
                    "k": write(cache[slot]["k"], val["k_new"]),
                    "v": write(cache[slot]["v"], val["v_new"]),
                }
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stage slicing (pipeline-parallel cold starts)
# ---------------------------------------------------------------------------


def slice_blocks(params_or_cache, p0: int, p1: int):
    """Slice the stacked period axis [p0, p1) of a blocks/cache tree."""
    return jax.tree.map(lambda a: a[p0:p1], params_or_cache)


def stage_period_ranges(n_periods: int, n_stages: int):
    """Balanced contiguous period ranges, one per pipeline stage."""
    base, rem = divmod(n_periods, n_stages)
    ranges, start = [], 0
    for i in range(n_stages):
        size = base + (1 if i < rem else 0)
        ranges.append((start, start + size))
        start += size
    return ranges
