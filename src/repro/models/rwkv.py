"""RWKV6 ('Finch') time-mix with data-dependent decay.

Backbone fidelity: token-shift lerps + LoRA-parameterized decay + WKV6
recurrence (via kernels.ops.wkv6) + gated output. The channel-mix MLP is the
shared dense SwiGLU from mlp.py (noted simplification, DESIGN.md §5).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.common import ParamDef, silu

LORA_R = 64


def rwkv_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim
    assert h * hd == d, "rwkv requires n_heads*head_dim == d_model"
    return {
        "mu_r": ParamDef((d,), ("embed",), init="ones", scale=0.5),
        "mu_k": ParamDef((d,), ("embed",), init="ones"),
        "mu_v": ParamDef((d,), ("embed",), init="ones"),
        "mu_g": ParamDef((d,), ("embed",), init="ones"),
        "mu_w": ParamDef((d,), ("embed",), init="ones"),
        "w_r": ParamDef((d, d), ("embed", "heads")),
        "w_k": ParamDef((d, d), ("embed", "heads")),
        "w_v": ParamDef((d, d), ("embed", "heads")),
        "w_g": ParamDef((d, d), ("embed", "heads")),
        "w_o": ParamDef((d, d), ("heads", "embed")),
        "decay_base": ParamDef((d,), ("embed",), init="zeros"),
        "decay_A": ParamDef((d, LORA_R), ("embed", None)),
        "decay_B": ParamDef((LORA_R, d), (None, "embed")),
        "u": ParamDef((h, hd), ("heads", "head_dim"), init="zeros"),
        "ln_w": ParamDef((d,), ("embed",), init="ones"),
        "ln_b": ParamDef((d,), ("embed",), init="zeros"),
        "norm": ParamDef((d,), ("embed",), init="ones"),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                         jnp.float32),
    }


RWKV_CACHE_AXES = {
    "shift": ("batch", None, "embed"),
    "wkv": ("batch", "heads", "head_dim", None),
}


def _token_shift(x, shift_state):
    """Previous-token tensor: concat(state, x[:, :-1])."""
    prev = jnp.concatenate([shift_state.astype(x.dtype), x[:, :-1]], axis=1)
    return prev


def _heads(x, h, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, h, hd)


def rwkv_mixer(cfg: ModelConfig, p: dict, x, *, cache: Optional[dict] = None,
               decode: bool = False) -> Tuple:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    shift_state = (cache["shift"] if cache is not None
                   else jnp.zeros((b, 1, d), x.dtype))
    prev = _token_shift(x, shift_state)

    def lerp(mu):
        m = jax.nn.sigmoid(p[mu].astype(jnp.float32)).astype(x.dtype)
        return x * m + prev * (1 - m)

    xr, xk, xv, xg, xw = (lerp(m) for m in
                          ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"))
    r = _heads(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype)), h, hd)
    k = _heads(jnp.einsum("bsd,de->bse", xk, p["w_k"].astype(x.dtype)), h, hd)
    v = _heads(jnp.einsum("bsd,de->bse", xv, p["w_v"].astype(x.dtype)), h, hd)
    g = jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(x.dtype))

    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_A"].astype(jnp.float32))
    w = (p["decay_base"].astype(jnp.float32)
         + lora @ p["decay_B"].astype(jnp.float32))        # (b,s,d)
    w = _heads(w, h, hd)

    state = cache["wkv"] if cache is not None else None
    y, new_state = ops.wkv6(r, k, v, w, p["u"].astype(jnp.float32), state)

    yf = y.reshape(b, s, d).astype(jnp.float32)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + 1e-5)
    yn = yn * p["ln_w"].astype(jnp.float32) + p["ln_b"].astype(jnp.float32)
    out = (yn.astype(x.dtype) * silu(g))
    out = jnp.einsum("bse,ed->bsd", out, p["w_o"].astype(x.dtype))

    new_cache = {"shift": x[:, -1:, :], "wkv": new_state}
    return constrain(out, "batch", "seq", "embed"), new_cache
