"""Dense gated MLP and sort-based dropping MoE (MaxText-style dispatch:
no one-hot einsum, FLOPs stay proportional to *active* experts)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef, silu


# ---------------------------------------------------------------------------
# Dense gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def dense_mlp_defs(cfg: ModelConfig, d_ff: int = 0) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    return {
        "w_gate": ParamDef((d, ff), ("embed", "ffn")),
        "w_up": ParamDef((d, ff), ("embed", "ffn")),
        "w_down": ParamDef((ff, d), ("ffn", "embed")),
        "norm": ParamDef((d,), ("embed",), init="ones"),
    }


def dense_mlp(p: dict, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = constrain(silu(g) * u, "batch", "seq", "ffn")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    # seq-sharded output -> reduce-scatter under SP (§Perf it.2)
    return constrain(y, "batch", "act_seq", "embed")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, eff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    # expert-parallel ('expert') vs tensor-parallel-inside-expert ('ffn')
    if cfg.expert_sharding == "expert":
        ax = ("experts", "embed", None)
        ax_out = ("experts", None, "embed")
    else:
        ax = (None, "embed", "expert_ffn")
        ax_out = (None, "expert_ffn", "embed")
    defs = {
        "router": ParamDef((d, e), ("embed", None)),
        "w_gate": ParamDef((e, d, eff), ax),
        "w_up": ParamDef((e, d, eff), ax),
        "w_down": ParamDef((e, eff, d), ax_out),
        "norm": ParamDef((d,), ("embed",), init="ones"),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * eff
        defs.update({
            "shared_gate": ParamDef((d, sff), ("embed", "ffn")),
            "shared_up": ParamDef((d, sff), ("embed", "ffn")),
            "shared_down": ParamDef((sff, d), ("ffn", "embed")),
        })
    return defs


def _exclusive_cumsum(x):
    return jnp.cumsum(x) - x


def moe_mlp(cfg: ModelConfig, p: dict, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss).

    Group-batched dropping MoE: tokens are split into G groups aligned with
    the data-parallel sharding; routing, the stable sort, the capacity
    scatter and the combine all carry the G batch dim, so GSPMD keeps every
    buffer O(local_tokens) per device (a global argsort+gather would be
    replicated — computed indices defeat sharding propagation). Capacity is
    per group, as in expert-parallel deployments.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g_ = 16 if t % 16 == 0 and t >= 16 else 1
    tg = t // g_
    xg = constrain(x.reshape(g_, tg, d), "batch", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                 # (g,tg,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style), computed globally
    frac_tokens = jnp.mean(jax.nn.one_hot(top_i[..., 0], e), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    rows = tg * k
    capacity = int(-(-rows // e) * cfg.capacity_factor)
    if rows // e < 8:
        capacity = rows          # small-batch no-drop mode (decode path)
    capacity = max(capacity, 4)

    row_expert = top_i.reshape(g_, rows)
    row_weight = top_w.reshape(g_, rows)
    row_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (g_, rows))

    order = jnp.argsort(row_expert, axis=1, stable=True)
    se = jnp.take_along_axis(row_expert, order, axis=1)
    st = jnp.take_along_axis(row_token, order, axis=1)
    sw = jnp.take_along_axis(row_weight, order, axis=1)

    counts = jnp.zeros((g_, e), jnp.int32).at[
        jnp.arange(g_)[:, None], se].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts
    rank = jnp.arange(rows)[None] - jnp.take_along_axis(starts, se, axis=1)
    keep = rank < capacity
    slot = jnp.where(keep, se * capacity + rank, e * capacity)

    # vmapped row gathers/scatters: index tensors stay (g, rows) — a plain
    # take_along_axis/.at[] here broadcasts u32 indices to (g, rows, d)
    gathered = jax.vmap(lambda xr, idx: xr[idx])(xg, st)        # (g,rows,d)
    buf = jax.vmap(
        lambda vals, sl: jnp.zeros((e * capacity + 1, d),
                                   x.dtype).at[sl].set(vals))(gathered, slot)
    h = buf[:, : e * capacity].reshape(g_, e, capacity, d)
    h = constrain(h, "batch", "experts" if cfg.expert_sharding == "expert"
                  else None, None, "embed")

    wg = p["w_gate"].astype(x.dtype)
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    gact = jnp.einsum("gecd,edf->gecf", h, wg)
    uact = jnp.einsum("gecd,edf->gecf", h, wu)
    hidden = silu(gact) * uact
    if cfg.expert_sharding == "expert":
        hidden = constrain(hidden, "batch", "experts", None, None)
    else:
        hidden = constrain(hidden, "batch", None, None, "expert_ffn")
    y_e = jnp.einsum("gecf,efd->gecd", hidden, wd)
    y_e = constrain(y_e, "batch",
                    "experts" if cfg.expert_sharding == "expert" else None,
                    None, "embed")

    yf = y_e.reshape(g_, e * capacity, d)
    safe_slot = jnp.minimum(slot, e * capacity - 1)
    y_rows = jax.vmap(lambda yr, idx: yr[idx])(yf, safe_slot)
    y_rows = jnp.where(keep[..., None], y_rows, 0.0)
    y_rows = y_rows * sw[..., None].astype(x.dtype)
    out = jax.vmap(
        lambda vals, idx: jnp.zeros((tg, d), x.dtype).at[idx].add(vals))(
        y_rows, st)
    out = out.reshape(b, s, d)

    if cfg.n_shared_experts:
        sh = {"w_gate": p["shared_gate"], "w_up": p["shared_up"],
              "w_down": p["shared_down"]}
        out = out + dense_mlp(sh, x)
    return constrain(out, "batch", "seq", "embed"), aux.astype(jnp.float32)
