"""Pipeline-parallel serving as compiled SPMD (the paper's mechanism in
production form): a GPipe-style microbatched prefill over a
(stage, data, model) mesh. Stages exchange activations with
``jax.lax.ppermute``; each stage owns a contiguous slice of the stacked
period axis (the same slice a hydra cold-start worker fetches).

This is the dry-run proof that the cold-start pipeline groups of
serving/engine.py lower to a single SPMD executable on real hardware:

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape prefill_32k --policy ppipe
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import use_mesh
from repro.models import transformer
from repro.models.model import Model


def supports(cfg: ModelConfig, n_stages: int = 4) -> bool:
    return (not cfg.is_encdec and cfg.n_periods % n_stages == 0
            and cfg.family in ("dense", "vlm", "moe"))


def make_pp_prefill(cfg: ModelConfig, mesh, batch: int, seq: int,
                    n_stages: int = 4, n_micro: int = 8):
    """Returns (fn, arg_structs, in_shardings, out_shardings, donate) for a
    pipelined prefill producing last-token logits."""
    assert supports(cfg, n_stages)
    assert batch % n_micro == 0
    model = Model(cfg)
    mb = batch // n_micro
    dt = jnp.dtype(cfg.dtype)

    def step(params, tokens):
        stage = jax.lax.axis_index("stage")
        mbs = tokens.reshape(n_micro, mb, seq)
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (mb, seq))
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def loop(x, t):
            # hand the previous activation to the next stage
            x = jax.lax.ppermute(x, "stage", perm)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            emb = transformer.embed(cfg, params, mbs[mb_idx], positions,
                                    dtype=dt)
            x = jnp.where(stage == 0, emb, x)
            x, _, _ = transformer.run_blocks(cfg, params["blocks"], x,
                                             positions)
            logits = transformer.head(cfg, params, x[:, -1:])[:, 0]
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            out_t = jnp.where(emit, logits, jnp.zeros_like(logits))
            return x, out_t

        x0 = jnp.zeros((mb, seq, cfg.d_model), dt)
        _, outs = jax.lax.scan(loop, x0, jnp.arange(n_micro + n_stages - 1))
        logits = outs[n_stages - 1:]               # (n_micro, mb, V)
        # broadcast from the last stage; f32 psum sidesteps XLA:CPU's
        # AllReducePromotion crash on sub-f32 reduce collectives
        logits = jax.lax.psum(logits.astype(jnp.float32), "stage").astype(dt)
        return logits.reshape(batch, cfg.padded_vocab)

    # physical specs: stacked period axis -> 'stage'; TP dims -> 'model'
    with use_mesh(mesh, {"layers": "stage", "batch": ("data",)}):
        full_specs = model.specs()
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), full_specs,
                        is_leaf=lambda x: isinstance(x, P))
    # shard_map manual specs mention only the 'stage' axis
    manual_specs = jax.tree.map(
        lambda s: P(*[p if p == "stage" else None for p in s]),
        full_specs, is_leaf=lambda x: isinstance(x, P))

    mapped = shard_map(
        step, mesh=mesh, axis_names=frozenset({"stage"}),
        in_specs=(manual_specs, P()),
        out_specs=P(),
        check_vma=False,
    )

    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    tok_sh = NamedSharding(mesh, P("data"))
    logits_sh = NamedSharding(mesh, P("data", "model"))
    return (mapped, (model.structs(), tok), (p_sh, tok_sh), logits_sh, ())
