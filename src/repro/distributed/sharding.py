"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a ``ShardingRules`` table maps those to physical mesh axes.  Outside a mesh
context everything is a no-op, so the same model code runs on 1 CPU device
and on the 512-chip production mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


# Default logical->physical translation for the production (data, model) mesh.
DEFAULT_RULES = {
    "batch": ("data",),
    "seq": None,            # activations: sequence replicated by default
    "act_seq": None,        # layer-boundary residual stream; train/prefill
                            # map this to 'model' (Megatron-style sequence
                            # parallelism) so saved activations shard 16-way
    "kv_seq": None,         # long-context decode overrides this to 'model' (SP)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": "model",   # used instead of 'experts' when n_experts < TP
    "conv": None,
    "state": None,
    "dt_rank": None,
    "layers": None,
    "stage": "stage",       # only present on PP dry-run meshes
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + logical rules for ``constrain`` / ``spec_for``."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mappings to axes the mesh doesn't actually have
    if mesh is not None:
        names = set(mesh.axis_names)

        def _ok(ax):
            if ax is None:
                return None
            if isinstance(ax, str):
                return ax if ax in names else None
            kept = tuple(a for a in ax if a in names)
            return kept if kept else None

        merged = {k: _ok(v) for k, v in merged.items()}
    _CTX.rules = merged
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def resolve(logical_axes: Sequence[Optional[str]]) -> P:
    """Translate a tuple of logical axis names into a PartitionSpec."""
    rules = _CTX.rules
    parts, used = [], set()
    for name in logical_axes:
        ax = rules.get(name) if name else None
        # a physical axis may appear at most once in a spec
        if ax is not None:
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat if a not in used)
            used.update(flat)
            ax = flat if len(flat) != 1 else flat[0]
            if isinstance(ax, tuple) and not ax:
                ax = None
        parts.append(ax)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_for(logical_axes: Sequence[Optional[str]]):
    """NamedSharding for the active mesh (or None outside a mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(logical_axes))


def constrain(x, *logical_axes):
    """with_sharding_constraint under the active mesh; identity without one."""
    s = spec_for(logical_axes)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
