"""Manual-collective tensor parallelism via shard_map (§Perf it.6).

GSPMD's CPU pipeline reduces TP partial sums in f32 (double volume) and
never rewrites all-reduce -> reduce-scatter under sequence parallelism
(measured in EXPERIMENTS.md §Perf). This module hand-schedules the
Megatron-SP collective pattern for dense GQA prefill:

  per sublayer:  x_seqshard --all_gather(bf16)--> x_full
                 local heads compute
                 partial out --psum_scatter(bf16)--> y_seqshard

One bf16 all-gather + one bf16 reduce-scatter per sublayer — vs GSPMD's
f32 all-gather + f32 all-reduce. KV heads (< TP) are computed replicated
per rank from an all-gathered w_k/w_v (weights are small); q heads are
TP-local (requires n_heads % tp == 0).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import rmsnorm


def supports(cfg: ModelConfig, tp: int = 16) -> bool:
    return (not cfg.is_moe and not cfg.is_encdec and not cfg.sub_quadratic
            and cfg.n_heads % tp == 0 and cfg.pos_embed == "rope"
            and cfg.d_model % tp == 0 and cfg.d_ff % tp == 0)


def _param_specs(cfg: ModelConfig) -> dict:
    """Physical specs matching models' ParamDef axes on (data, model)."""
    d = {
        "embed": {"tok": P("model", None)},
        "blocks": {"slot00": {
            "mixer": {
                "w_q": P(None, None, "model"),
                "w_k": P(None, None, "model"),
                "w_v": P(None, None, "model"),
                "w_o": P(None, "model", None),
                "norm": P(None, None),
            },
            "mlp": {
                "w_gate": P(None, None, "model"),
                "w_up": P(None, None, "model"),
                "w_down": P(None, "model", None),
                "norm": P(None, None),
            },
        }},
        "final_norm": P(None),
        "lm_head": P(None, "model"),
    }
    if cfg.qkv_bias:
        d["blocks"]["slot00"]["mixer"].update({
            "b_q": P(None, "model"), "b_k": P(None, "model"),
            "b_v": P(None, "model")})
    return d


def make_manual_prefill(cfg: ModelConfig, mesh, batch: int, seq: int,
                        tp: int = 16):
    """Returns (fn, arg_structs, in_shardings, out_shardings, donate)."""
    assert supports(cfg, tp), cfg.name
    from jax.sharding import NamedSharding

    cdt = jnp.dtype(cfg.dtype)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hq_loc = hq // tp
    d, ff = cfg.d_model, cfg.d_ff
    n_layers = cfg.n_periods
    s_loc = seq // tp

    def step(params, tokens):
        # --- manual region: everything below sees per-'model'-rank shards,
        # 'data' stays automatic (GSPMD) ------------------------------------
        rank = jax.lax.axis_index("model")

        # embedding: local vocab shard + one bf16 psum
        vshard = cfg.padded_vocab // tp
        tok_w = params["embed"]["tok"]              # (V/tp, d) local
        local_ids = tokens - rank * vshard
        in_range = (local_ids >= 0) & (local_ids < vshard)
        x = jnp.take(tok_w, jnp.clip(local_ids, 0, vshard - 1), axis=0)
        x = jnp.where(in_range[..., None], x, 0).astype(jnp.float32)
        # NOTE: XLA:CPU's AllReducePromotion pass crashes on sub-f32
        # reduce collectives (see EXPERIMENTS.md §Perf it.6) — reduce in
        # f32, cast after. all_gathers stay bf16 (no arithmetic, no pass).
        x = jax.lax.psum(x, "model").astype(cdt)  # (B, S, d)
        # sequence-shard the residual stream
        x = jax.lax.dynamic_slice_in_dim(x, rank * s_loc, s_loc, 1)

        positions = jnp.broadcast_to(jnp.arange(seq)[None],
                                     (tokens.shape[0], seq))

        def layer(x, pslice):
            mixer, mlp = pslice["mixer"], pslice["mlp"]
            # ---- attention sublayer
            xin = rmsnorm(x, mixer["norm"], cfg.norm_eps)
            x_full = jax.lax.all_gather(xin, "model", axis=1, tiled=True)
            q = jnp.einsum("bsd,dh->bsh", x_full,
                           mixer["w_q"].astype(x.dtype))
            if cfg.qkv_bias:
                q = q + mixer["b_q"].astype(x.dtype)
            b = q.shape[0]
            q = q.reshape(b, seq, hq_loc, hd)
            # kv: replicate heads per rank (w_k/w_v shards all-gathered —
            # weights are tiny next to activations)
            w_k = jax.lax.all_gather(mixer["w_k"], "model", axis=1,
                                     tiled=True)
            w_v = jax.lax.all_gather(mixer["w_v"], "model", axis=1,
                                     tiled=True)
            k = jnp.einsum("bsd,dh->bsh", x_full, w_k.astype(x.dtype))
            v = jnp.einsum("bsd,dh->bsh", x_full, w_v.astype(x.dtype))
            if cfg.qkv_bias:
                k = k + jax.lax.all_gather(mixer["b_k"], "model",
                                           tiled=True).astype(x.dtype)
                v = v + jax.lax.all_gather(mixer["b_v"], "model",
                                           tiled=True).astype(x.dtype)
            k = k.reshape(b, seq, hkv, hd)
            v = v.reshape(b, seq, hkv, hd)
            from repro.models.common import apply_rope
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            # GQA: select each local q head's kv head from the replicated set
            group = hq // hkv
            q_global = rank * hq_loc + jnp.arange(hq_loc)
            kv_sel = q_global // group
            k_sel = jnp.take(k, kv_sel, axis=2)
            v_sel = jnp.take(v, kv_sel, axis=2)
            out = ops.flash_attention(q, k_sel, v_sel, causal=True)
            y = jnp.einsum("bsh,hd->bsd", out.reshape(b, seq, hq_loc * hd),
                           mixer["w_o"].astype(x.dtype))
            # ONE bf16 reduce-scatter back to the seq shard
            y = jax.lax.psum_scatter(y.astype(jnp.float32), "model",
                                     scatter_dimension=1, tiled=True)
            x = x + y.astype(x.dtype)
            # ---- mlp sublayer
            xin = rmsnorm(x, mlp["norm"], cfg.norm_eps)
            x_full = jax.lax.all_gather(xin, "model", axis=1, tiled=True)
            g = jnp.einsum("bsd,df->bsf", x_full,
                           mlp["w_gate"].astype(x.dtype))
            u = jnp.einsum("bsd,df->bsf", x_full,
                           mlp["w_up"].astype(x.dtype))
            h = jax.nn.silu(g) * u
            y = jnp.einsum("bsf,fd->bsd", h, mlp["w_down"].astype(x.dtype))
            y = jax.lax.psum_scatter(y.astype(jnp.float32), "model",
                                     scatter_dimension=1, tiled=True)
            x = x + y.astype(x.dtype)
            # cache slices: this rank keeps its kv_seq shard
            k_sh = jax.lax.dynamic_slice_in_dim(k, rank * s_loc, s_loc, 1)
            v_sh = jax.lax.dynamic_slice_in_dim(v, rank * s_loc, s_loc, 1)
            return x, {"k": k_sh, "v": v_sh}

        x, cache = jax.lax.scan(
            lambda c, p: layer(c, p), x, params["blocks"]["slot00"])

        # head on the final token (lives on the last rank's shard)
        x_full = jax.lax.all_gather(
            rmsnorm(x, params["final_norm"], cfg.norm_eps),
            "model", axis=1, tiled=True)
        last = x_full[:, -1]
        logits = jnp.einsum("bd,dv->bv", last,
                            params["lm_head"].astype(last.dtype))
        return logits, cache

    pspecs = _param_specs(cfg)
    tok_spec = P(("pod", "data"), None)
    logits_spec = P(("pod", "data"), "model")
    cache_spec = {"k": P(None, ("pod", "data"), "model", None, None),
                  "v": P(None, ("pod", "data"), "model", None, None)}

    def drop_pod(spec):
        if "pod" in mesh.axis_names:
            return spec
        parts = []
        for part in spec:
            if isinstance(part, tuple):
                part = tuple(a for a in part if a in mesh.axis_names)
                part = part[0] if len(part) == 1 else (part or None)
            parts.append(part)
        return P(*parts)

    tok_spec = drop_pod(tok_spec)
    logits_spec = drop_pod(logits_spec)
    cache_spec = jax.tree.map(drop_pod, cache_spec,
                              is_leaf=lambda x: isinstance(x, P))

    mapped = shard_map(
        step, mesh=mesh, axis_names=frozenset({"model"}),
        in_specs=(jax.tree.map(
            lambda s: P(*[p if p == "model" else None for p in s]),
            pspecs, is_leaf=lambda x: isinstance(x, P)), P()),
        out_specs=(P(None, "model"), {"k": P(None, None, "model", None,
                                             None),
                                      "v": P(None, None, "model", None,
                                             None)}),
        check_vma=False,
    )

    # struct args (dense path only touches these leaves)
    from repro.models.model import Model
    model = Model(cfg)
    structs = model.structs()
    arg_structs = (structs,
                   jax.ShapeDtypeStruct((batch, seq), jnp.int32))
    ns = lambda s: jax.sharding.NamedSharding(mesh, s)
    in_sh = (jax.tree.map(lambda s: ns(drop_pod(s)), pspecs,
                          is_leaf=lambda x: isinstance(x, P)),
             ns(tok_spec))
    out_sh = (ns(logits_spec), jax.tree.map(
        lambda s: ns(s), cache_spec, is_leaf=lambda x: isinstance(x, P)))
    return mapped, arg_structs, in_sh, out_sh, ()
