"""Version-compat layer for Pallas TPU across JAX releases.

JAX renamed ``pltpu.TPUCompilerParams`` (0.4.x) to ``pltpu.CompilerParams``
(0.5+). Kernels import :func:`compiler_params` instead of naming the class so
they run on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(**kwargs):
    """Build the TPU compiler-params object under whichever name exists."""
    return _COMPILER_PARAMS_CLS(**kwargs)
