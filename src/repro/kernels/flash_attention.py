"""Pallas TPU kernel: causal GQA flash attention (prefill / train).

Grid (B, Hq, nq, nk); the nk axis is the sequential ("arbitrary") dimension
with the online-softmax running state (m, l, acc) held in VMEM scratch.
Blocks are MXU-aligned (default 128x128); K/V index maps fold GQA by
mapping query head h to KV head h // group.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, q_block: int, kv_block: int,
            seq_q: int, seq_kv: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (qb, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (kb, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    qpos = (iq * q_block + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0))
    kpos = (ik * kv_block
            + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1))
    mask = kpos < seq_kv                          # kv padding
    if causal:
        mask = mask & (kpos <= qpos)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    scale: Optional[float] = None,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = False):
    """q (B,Sq,Hq,hd); k,v (B,Sk,Hkv,hd) -> (B,Sq,Hq,hd)."""
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qb = min(q_block, max(sq, 8))
    kb = min(kv_block, max(sk, 8))

    sq_p = -(-sq // qb) * qb
    sk_p = -(-sk // kb) * kb
    qt = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)          # (B,Hq,Sq,hd)
    kt = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)          # (B,Hkv,Sk,hd)
    vt = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)

    grid = (b, hq, sq_p // qb, sk_p // kb)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, q_block=qb, kv_block=kb,
        seq_q=sq, seq_kv=sk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, hd), lambda bi, h, iq, ik: (bi, h, iq, 0)),
            pl.BlockSpec((1, 1, kb, hd),
                         lambda bi, h, iq, ik, g=group: (bi, h // g, ik, 0)),
            pl.BlockSpec((1, 1, kb, hd),
                         lambda bi, h, iq, ik, g=group: (bi, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, hd),
                               lambda bi, h, iq, ik: (bi, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, hd), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)

    return out.transpose(0, 2, 1, 3)[:, :sq]
