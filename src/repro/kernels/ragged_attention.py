"""Pallas TPU kernel: fused ragged-batch paged attention.

One launch serves a whole mixed ``ScheduleBatch``: the step's query
tokens — prefill chunks of varying length and history, plus decode rows —
are flattened into a ragged ``(total_tokens, Hq, hd)`` layout with
per-token ``(row, pos)`` descriptors. The block-table row ids and the
block tables themselves ride as scalar-prefetch operands so the K/V
BlockSpec index maps gather each tile's pages before the body runs
(same machinery as ``paged_decode_attention``); per-token positions ride
as a VMEM input and drive the causal mask ``kpos <= pos[t]``, which
makes history length *dynamic* — no per-(chunk_len, hist_len) recompiles.

Layout contract (enforced by the host wrapper): ``total_tokens`` is a
multiple of ``tile_q`` and every request's token span is ``tile_q``
aligned, so each q tile reads exactly one block-table row
(``row[it * tile_q]``). Pad tokens carry ``pos = -1`` → fully masked →
exactly zero output.

The int8 variant loads quantized pages plus their per-row scale/zero
pools and fuses the dequant into the K/V loads — the pools never hold a
dequantized copy.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import _softmax_init, _softmax_step
from repro.kernels.pallas_compat import compiler_params

TILE_Q = 8


def _ragged_kernel(row_ref, bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                   tile_q: int, group: int):
    ib = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(ib == 0)
    def _init():
        _softmax_init(m_scr, l_scr, acc_scr)

    hd = q_ref.shape[-1]
    q = q_ref[...].astype(jnp.float32).reshape(tile_q * group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    kpos = ib * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                 # (1, bs)
    # per-row valid length: token at pos p attends kpos <= p, i.e.
    # kpos < p + 1; pad rows (pos = -1) mask everything
    pos_t = pos_ref[...].reshape(tile_q, 1)           # (TQ, 1)
    vlen = jnp.broadcast_to(pos_t[:, None], (tile_q, group, 1)
                            ).reshape(tile_q * group, 1) + 1
    _softmax_step(q, k, v, kpos, vlen, m_scr, l_scr, acc_scr, scale)

    @pl.when(ib == nb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype).reshape(
            o_ref.shape)


def _ragged_kernel_q8(row_ref, bt_ref, pos_ref, q_ref, k_ref, v_ref,
                      ks_ref, kz_ref, vs_ref, vz_ref, o_ref,
                      m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                      tile_q: int, group: int):
    ib = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(ib == 0)
    def _init():
        _softmax_init(m_scr, l_scr, acc_scr)

    hd = q_ref.shape[-1]
    q = q_ref[...].astype(jnp.float32).reshape(tile_q * group, hd)
    # dequant fused into the K/V loads: pages are int8, scale/zero f32
    ks = ks_ref[...].reshape(page_size, 1)
    kz = kz_ref[...].reshape(page_size, 1)
    vs = vs_ref[...].reshape(page_size, 1)
    vz = vz_ref[...].reshape(page_size, 1)
    k = k_ref[0, :, 0].astype(jnp.float32) * ks + kz  # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32) * vs + vz
    kpos = ib * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    pos_t = pos_ref[...].reshape(tile_q, 1)
    vlen = jnp.broadcast_to(pos_t[:, None], (tile_q, group, 1)
                            ).reshape(tile_q * group, 1) + 1
    _softmax_step(q, k, v, kpos, vlen, m_scr, l_scr, acc_scr, scale)

    @pl.when(ib == nb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype).reshape(
            o_ref.shape)


def ragged_paged_attention(q, k_pages, v_pages, tables, row, pos, *,
                           kv_quant=None, scale: Optional[float] = None,
                           tile_q: int = TILE_Q, interpret: bool = False):
    """q (T,Hq,hd) ragged query tokens; pages (N,bs,Hkv,hd); tables (B,nb)
    int32 page ids; row (T,) table row per token; pos (T,) absolute
    position per token (-1 = pad) -> (T,Hq,hd).

    T must be a multiple of ``tile_q`` and ``row`` constant within each
    tile (the host flattener aligns request spans to ``tile_q``).
    ``kv_quant`` switches to the fused-dequant int8 variant."""
    t, hq, hd = q.shape
    n_pages, page_size, hkv, _ = k_pages.shape
    nb = tables.shape[1]
    group = hq // hkv
    assert t % tile_q == 0, f"T={t} not a multiple of tile_q={tile_q}"
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(t, hkv, group, hd)
    row32 = row.astype(jnp.int32)
    tables32 = tables.astype(jnp.int32)
    pos2 = pos.astype(jnp.int32).reshape(1, t)

    grid = (t // tile_q, hkv, nb)
    page_idx = lambda it, h, ib, rw, bt: (bt[rw[it * tile_q], ib], 0, h, 0)
    in_specs = [
        pl.BlockSpec((1, tile_q), lambda it, h, ib, rw, bt: (0, it)),
        pl.BlockSpec((tile_q, 1, group, hd),
                     lambda it, h, ib, rw, bt: (it, h, 0, 0)),
        pl.BlockSpec((1, page_size, 1, hd), page_idx),
        pl.BlockSpec((1, page_size, 1, hd), page_idx),
    ]
    operands = [pos2, qg, k_pages, v_pages]
    if kv_quant is None:
        body = _ragged_kernel
    else:
        body = _ragged_kernel_q8
        qspec = pl.BlockSpec((1, page_size, 1),
                             lambda it, h, ib, rw, bt:
                             (bt[rw[it * tile_q], ib], 0, h))
        in_specs += [qspec] * 4
        operands += [kv_quant["k_scale"], kv_quant["k_zero"],
                     kv_quant["v_scale"], kv_quant["v_zero"]]

    kernel = functools.partial(body, scale=scale, page_size=page_size,
                               tile_q=tile_q, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_q, 1, group, hd),
                               lambda it, h, ib, rw, bt: (it, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tile_q * group, 1), jnp.float32),
            pltpu.VMEM((tile_q * group, 1), jnp.float32),
            pltpu.VMEM((tile_q * group, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hkv, group, hd), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(row32, tables32, *operands)

    return out.reshape(t, hq, hd)
