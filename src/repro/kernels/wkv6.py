"""Pallas TPU kernel: WKV6 (RWKV6 'Finch') recurrence, chunked over time.

Grid (B*H, nT): the time axis is sequential; the (hd, hd) state lives in
VMEM scratch across chunks. Inside a chunk a fori_loop applies the rank-1
recurrence per step:

    y_t = r_t @ S + (sum(r_t * u * k_t)) * v_t
    S   = exp(-exp(w_t))[:, None] * S + k_t^T v_t
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            y_ref, sT_ref, state_scr, *, chunk: int):
    it = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(it == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (ct, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, hd)
    decay = jnp.exp(-jnp.exp(w))              # (ct, hd)

    S0 = state_scr[...]

    def step(t, carry):
        S, ys = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)      # (1, hd)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        dt = jax.lax.dynamic_slice_in_dim(decay, t, 1, 0)
        y = jax.lax.dot_general(rt, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        bonus = jnp.sum(rt * u * kt, axis=-1, keepdims=True)  # (1,1)
        y = y + bonus * vt
        S_new = dt.T * S + kt.T @ vt
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y, t, 0)
        return S_new, ys

    S, ys = jax.lax.fori_loop(
        0, chunk, step, (S0, jnp.zeros((chunk, r.shape[1]), jnp.float32)))
    state_scr[...] = S
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(it == nt - 1)
    def _finish():
        sT_ref[0] = state_scr[...]


def wkv6(r, k, v, w, u, initial_state=None, *, chunk: int = 64,
         interpret: bool = False):
    """r,k,v,w (B,T,H,hd); u (H,hd); initial_state (B,H,hd,hd) fp32.
    Returns (y (B,T,H,hd), final_state (B,H,hd,hd))."""
    b, t, h, n = r.shape
    ct = min(chunk, max(t, 1))
    t_p = -(-t // ct) * ct
    bh = b * h

    def prep(x, pad_value=0.0):
        x = jnp.pad(x, ((0, 0), (0, t_p - t), (0, 0), (0, 0)),
                    constant_values=pad_value)
        return x.transpose(0, 2, 1, 3).reshape(bh, t_p, n)

    rr, kk, vv = prep(r), prep(k), prep(v)
    # padded steps must leave the state unchanged: decay=1 <= w -> -inf,
    # and contribute nothing: k row = 0 (handled since k pads with 0)
    ww = prep(w, pad_value=-1e9)
    uu = jnp.broadcast_to(u[None], (b, h, n)).reshape(bh, 1, n)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, n), jnp.float32)
    s0 = initial_state.reshape(bh, n, n).astype(jnp.float32)

    grid = (bh, t_p // ct)
    kernel = functools.partial(_kernel, chunk=ct)

    y, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, n), lambda i, it: (i, it, 0)),
            pl.BlockSpec((1, ct, n), lambda i, it: (i, it, 0)),
            pl.BlockSpec((1, ct, n), lambda i, it: (i, it, 0)),
            pl.BlockSpec((1, ct, n), lambda i, it: (i, it, 0)),
            pl.BlockSpec((1, 1, n), lambda i, it: (i, 0, 0)),
            pl.BlockSpec((1, n, n), lambda i, it: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, n), lambda i, it: (i, it, 0)),
            pl.BlockSpec((1, n, n), lambda i, it: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t_p, n), r.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rr, kk, vv, ww, uu, s0)

    y = y.reshape(b, h, t_p, n).transpose(0, 2, 1, 3)[:, :t]
    return y, sT.reshape(b, h, n, n)
