"""Pallas TPU kernels: GQA decode attention (flash-decoding).

Two variants share the online-softmax inner loop:

* ``decode_attention`` — slot-contiguous caches ``(B, S, Hkv, hd)``; grid
  (B, Hkv, nk) walks KV blocks sequentially with the softmax state in VMEM
  scratch. Per-sequence valid length ``kv_len`` masks the tail.
* ``paged_decode_attention`` — vLLM-style paged caches: a shared page pool
  ``(N, bs, Hkv, hd)`` addressed through a per-sequence block table
  ``(B, nb)``. The table is a scalar-prefetch operand so the K/V BlockSpec
  index maps gather the right page for each (sequence, step) before the
  kernel body runs.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1e30


def _softmax_step(q, k, v, kpos, valid_len, m_scr, l_scr, acc_scr,
                  scale: float):
    """One online-softmax accumulation over a KV tile, shared by the
    contiguous and paged kernels. q (G,hd); k,v (kb,hd); kpos (1,kb)
    absolute token positions of the tile. Fully-masked tiles (ragged
    tails, kv_len==0 rows) contribute exactly zero."""
    mask = kpos < valid_len
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)                   # (G, kb)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _softmax_init(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _softmax_finish(o_ref, m_scr, l_scr, acc_scr):
    denom = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, kv_block: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        _softmax_init(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (kb, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    kpos = ik * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (1, kv_block), 1)                  # (1, kb)
    _softmax_step(q, k, v, kpos, len_ref[0, 0], m_scr, l_scr, acc_scr,
                  scale)

    @pl.when(ik == nk - 1)
    def _finish():
        _softmax_finish(o_ref, m_scr, l_scr, acc_scr)


def decode_attention(q, k_cache, v_cache, kv_len, *,
                     scale: Optional[float] = None, kv_block: int = 512,
                     interpret: bool = False):
    """q (B,1,Hq,hd); caches (B,S,Hkv,hd); kv_len (B,) -> (B,1,Hq,hd)."""
    b, one, hq, hd = q.shape
    assert one == 1
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kb = min(kv_block, max(s, 8))
    s_p = -(-s // kb) * kb

    qg = q[:, 0].reshape(b, hkv, group, hd)           # (B,Hkv,G,hd)
    kt = jnp.pad(k_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)              # (B,Hkv,S,hd)
    vt = jnp.pad(v_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)
    lens = kv_len.astype(jnp.int32).reshape(b, 1)

    grid = (b, hkv, s_p // kb)
    kernel = functools.partial(_kernel, scale=scale, kv_block=kb)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, h, ik: (bi, 0)),
            pl.BlockSpec((1, 1, group, hd), lambda bi, h, ik: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, kb, hd), lambda bi, h, ik: (bi, h, ik, 0)),
            pl.BlockSpec((1, 1, kb, hd), lambda bi, h, ik: (bi, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda bi, h, ik: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qg, kt, vt)

    return out.reshape(b, 1, hq, hd)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int):
    ib = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(ib == 0)
    def _init():
        _softmax_init(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    kpos = ib * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                 # (1, bs)
    _softmax_step(q, k, v, kpos, len_ref[0, 0], m_scr, l_scr, acc_scr,
                  scale)

    @pl.when(ib == nb - 1)
    def _finish():
        _softmax_finish(o_ref, m_scr, l_scr, acc_scr)


def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_len, *,
                           scale: Optional[float] = None,
                           interpret: bool = False):
    """q (B,1,Hq,hd); pages (N,bs,Hkv,hd); block_tables (B,nb) int32 page
    ids; kv_len (B,) -> (B,1,Hq,hd).

    Rows of ``block_tables`` past a sequence's live length may hold any
    valid page id (conventionally 0): the ``kv_len`` mask zeroes their
    contribution.
    """
    b, one, hq, hd = q.shape
    assert one == 1
    n_pages, page_size, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q[:, 0].reshape(b, hkv, group, hd)           # (B,Hkv,G,hd)
    lens = kv_len.astype(jnp.int32).reshape(b, 1)
    tables = block_tables.astype(jnp.int32)

    grid = (b, hkv, nb)
    kernel = functools.partial(_paged_kernel, scale=scale,
                               page_size=page_size)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, h, ib, bt: (bi, 0)),
            pl.BlockSpec((1, 1, group, hd),
                         lambda bi, h, ib, bt: (bi, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, h, ib, bt: (bt[bi, ib], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, h, ib, bt: (bt[bi, ib], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda bi, h, ib, bt: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lens, qg, k_pages, v_pages)

    return out.reshape(b, 1, hq, hd)
