"""Pallas TPU kernel: GQA decode attention (flash-decoding).

One new query token per sequence attends to a long KV cache. Grid
(B, Hkv, nk): all G = Hq/Hkv query heads of a KV group are processed
together as a (G, hd) tile; the nk axis walks KV blocks sequentially with
the online-softmax state in VMEM scratch. Per-sequence valid length
``kv_len`` masks the tail.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, kv_block: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (kb, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    valid_len = len_ref[0, 0]

    kpos = ik * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (1, kv_block), 1)                  # (1, kb)
    mask = kpos < valid_len

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)                   # (G, kb)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, kv_len, *,
                     scale: Optional[float] = None, kv_block: int = 512,
                     interpret: bool = False):
    """q (B,1,Hq,hd); caches (B,S,Hkv,hd); kv_len (B,) -> (B,1,Hq,hd)."""
    b, one, hq, hd = q.shape
    assert one == 1
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kb = min(kv_block, max(s, 8))
    s_p = -(-s // kb) * kb

    qg = q[:, 0].reshape(b, hkv, group, hd)           # (B,Hkv,G,hd)
    kt = jnp.pad(k_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)              # (B,Hkv,S,hd)
    vt = jnp.pad(v_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0))
                 ).transpose(0, 2, 1, 3)
    lens = kv_len.astype(jnp.int32).reshape(b, 1)

    grid = (b, hkv, s_p // kb)
    kernel = functools.partial(_kernel, scale=scale, kv_block=kb)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, h, ik: (bi, 0)),
            pl.BlockSpec((1, 1, group, hd), lambda bi, h, ik: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, kb, hd), lambda bi, h, ik: (bi, h, ik, 0)),
            pl.BlockSpec((1, 1, kb, hd), lambda bi, h, ik: (bi, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda bi, h, ik: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qg, kt, vt)

    return out.reshape(b, 1, hq, hd)
