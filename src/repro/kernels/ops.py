"""Kernel dispatch layer.

Models call these wrappers; the backend is selected once per process:
  * 'pallas'     — real TPU kernels (pl.pallas_call, compiled)
  * 'interpret'  — same kernels, interpret=True (CPU correctness runs)
  * 'ref'        — blocked pure-jnp implementations (default on CPU; also
                   what the dry-run lowers, so the compiled HLO is flash-like)

Env knobs (read once, overridable via the setters):
  REPRO_KERNEL_BACKEND = pallas | interpret | ref
  REPRO_DECODE_MODE    = scatter | append | paged
  REPRO_ATTN_MODE      = masked_full | causal_skip
  REPRO_SANITIZE       = 1 | 0 — correctness tooling (analysis/): the
                         engine KV-lifecycle sanitizer and the Pallas
                         launch checker run on the traced kernel calls
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref

DECODE_MODES = ("scatter", "append", "paged")

_BACKEND = None
_ATTN_MODE = os.environ.get("REPRO_ATTN_MODE", "masked_full")
_DECODE_MODE = os.environ.get("REPRO_DECODE_MODE", "scatter")
_SANITIZE = os.environ.get("REPRO_SANITIZE", "0").lower() \
    not in ("", "0", "off", "false")
assert _ATTN_MODE in ("masked_full", "causal_skip"), \
    f"REPRO_ATTN_MODE={_ATTN_MODE!r}: want masked_full|causal_skip"
assert _DECODE_MODE in DECODE_MODES, \
    f"REPRO_DECODE_MODE={_DECODE_MODE!r}: want {'|'.join(DECODE_MODES)}"


def set_decode_mode(mode: str):
    global _DECODE_MODE
    assert mode in DECODE_MODES
    _DECODE_MODE = mode


def decode_mode() -> str:
    return _DECODE_MODE


def set_sanitize_mode(on: bool):
    global _SANITIZE
    _SANITIZE = bool(on)


def sanitize_mode() -> bool:
    return _SANITIZE


def set_attention_mode(mode: str):
    global _ATTN_MODE
    assert mode in ("masked_full", "causal_skip")
    _ATTN_MODE = mode


def attention_mode() -> str:
    return _ATTN_MODE


def backend() -> str:
    global _BACKEND
    if _BACKEND is None:
        forced = os.environ.get("REPRO_KERNEL_BACKEND")
        if forced:
            _BACKEND = forced
        else:
            plat = jax.default_backend()
            _BACKEND = "pallas" if plat == "tpu" else "ref"
    return _BACKEND


def set_backend(name: str):
    global _BACKEND
    assert name in ("pallas", "interpret", "ref")
    _BACKEND = name


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    kv_len=None, scale: Optional[float] = None,
                    q_block: int = 512, kv_block: int = 1024):
    """Prefill/train attention. q (B,Sq,Hq,hd); k,v (B,Sk,Hkv,hd)."""
    be = backend()
    if be in ("pallas", "interpret") and kv_len is None:
        from repro.kernels import flash_attention as _fa
        return _fa.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset, scale=scale,
            interpret=(be == "interpret"))
    if q.shape[1] * k.shape[1] <= 1 << 20:   # tiny: naive is cheaper to trace
        return _ref.mha_reference(q, k, v, causal=causal, q_offset=q_offset,
                                  kv_len=kv_len, scale=scale)
    if causal and _ATTN_MODE == "causal_skip":
        return _ref.flash_attention_blocked_skip(
            q, k, v, q_offset=q_offset, kv_len=kv_len, scale=scale)
    return _ref.flash_attention_blocked(
        q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
        q_block=q_block, kv_block=kv_block, scale=scale)


def decode_attention(q, k_cache, v_cache, kv_len, *,
                     scale: Optional[float] = None, kv_block: int = 512):
    """Single-token decode vs long KV. q (B,1,Hq,hd); cache (B,S,Hkv,hd)."""
    be = backend()
    if be in ("pallas", "interpret"):
        from repro.kernels import decode_attention as _da
        return _da.decode_attention(q, k_cache, v_cache, kv_len, scale=scale,
                                    kv_block=kv_block,
                                    interpret=(be == "interpret"))
    return _ref.decode_attention_reference(q, k_cache, v_cache, kv_len,
                                           scale=scale)


def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_len, *,
                           scale: Optional[float] = None):
    """Single-token decode against a paged KV pool. q (B,1,Hq,hd);
    pages (N,bs,Hkv,hd); block_tables (B,nb) page ids; kv_len (B,)."""
    be = backend()
    if _SANITIZE:
        from repro.analysis import kernelcheck
        kernelcheck.check_paged_decode(q, k_pages, v_pages, block_tables,
                                       kv_len, backend=be)
    if be in ("pallas", "interpret"):
        from repro.kernels import decode_attention as _da
        return _da.paged_decode_attention(
            q, k_pages, v_pages, block_tables, kv_len, scale=scale,
            interpret=(be == "interpret"))
    return _ref.paged_decode_attention_reference(
        q, k_pages, v_pages, block_tables, kv_len, scale=scale)


def ragged_paged_attention(q, k_pages, v_pages, tables, row, pos, *,
                           kv_quant=None, scale: Optional[float] = None,
                           tile_q: int = 8):
    """Fused ragged-batch attention over a paged pool: one launch serves a
    whole mixed prefill-chunk + decode step. q (T,Hq,hd) flattened query
    tokens; pages (N,bs,Hkv,hd); tables (B,nb); row (T,) table row per
    token; pos (T,) absolute position per token (-1 = pad). ``kv_quant``
    carries int8 pools' scale/zero leaves (dequant fused into the K/V
    loads)."""
    be = backend()
    if _SANITIZE:
        from repro.analysis import kernelcheck
        kernelcheck.check_ragged_paged(q, k_pages, v_pages, tables, row,
                                       pos, kv_quant=kv_quant,
                                       tile_q=tile_q, backend=be)
    if be in ("pallas", "interpret"):
        from repro.kernels import ragged_attention as _ra
        return _ra.ragged_paged_attention(
            q, k_pages, v_pages, tables, row, pos, kv_quant=kv_quant,
            scale=scale, tile_q=tile_q, interpret=(be == "interpret"))
    return _ref.ragged_paged_attention_reference(
        q, k_pages, v_pages, tables, row, pos, kv_quant=kv_quant,
        scale=scale)


def wkv6(r, k, v, w, u, initial_state=None, *, chunk: int = 64):
    """RWKV6 recurrence. r,k,v,w (B,T,H,hd); u (H,hd)."""
    be = backend()
    if be in ("pallas", "interpret"):
        from repro.kernels import wkv6 as _wkv
        return _wkv.wkv6(r, k, v, w, u, initial_state, chunk=chunk,
                         interpret=(be == "interpret"))
    return _ref.wkv6_chunked(r, k, v, w, u, initial_state, chunk=chunk)
