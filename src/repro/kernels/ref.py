"""Pure-jnp oracles for every Pallas kernel, plus blocked (flash-style)
jnp implementations used by the models at scale (memory-sane HLO).

Shapes:
  q          (B, Sq, Hq, hd)
  k, v       (B, Sk, Hkv, hd)      Hq % Hkv == 0 (GQA)
  kv_len     (B,) int32 — valid cache length per sequence (optional)
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, n_q_heads):
    """(B,S,Hkv,hd) -> (B,S,Hq,hd) by repeating KV heads."""
    b, s, hkv, hd = k.shape
    rep = n_q_heads // hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# ---------------------------------------------------------------------------
# Naive attention oracle (materializes the score matrix) — unit-test scale.
# ---------------------------------------------------------------------------


def mha_reference(q, k, v, *, causal: bool = True, q_offset: int = 0,
                  kv_len=None, scale: Optional[float] = None):
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kx = _gqa_expand(k, hq)
    vx = _gqa_expand(v, hq)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    mask = jnp.ones((sq, sk), bool)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
    mask = jnp.broadcast_to(mask[None, None], (b, 1, sq, sk))
    if kv_len is not None:
        valid = jnp.arange(sk)[None, None, None, :] < kv_len[:, None, None, None]
        mask = mask & valid
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Blocked flash attention, pure jnp — the scalable oracle the models use on
# CPU and the reference the Pallas kernel is checked against.
# ---------------------------------------------------------------------------


def flash_attention_blocked(q, k, v, *, causal: bool = True, q_offset: int = 0,
                            kv_len=None, q_block: int = 512,
                            kv_block: int = 1024,
                            scale: Optional[float] = None):
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qb = min(q_block, sq)
    kb = min(kv_block, sk)
    # pad to block multiples
    sq_p = -(-sq // qb) * qb
    sk_p = -(-sk // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    nq, nk = sq_p // qb, sk_p // kb
    rep = hq // k.shape[2]

    qblocks = qp.reshape(b, nq, qb, hq, hd)
    kblocks = kp.reshape(b, nk, kb, k.shape[2], hd)
    vblocks = vp.reshape(b, nk, kb, k.shape[2], hd)

    kv_limit = kv_len if kv_len is not None else jnp.full((b,), sk, jnp.int32)

    def q_step(_, qi):
        qblk = qblocks[:, qi].astype(jnp.float32)          # (b,qb,hq,hd)
        qpos = qi * qb + jnp.arange(qb) + q_offset

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = _gqa_expand(kblocks[:, ki], hq).astype(jnp.float32)
            vblk = _gqa_expand(vblocks[:, ki], hq).astype(jnp.float32)
            kpos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk) * scale
            msk = jnp.ones((qb, kb), bool)
            if causal:
                msk = kpos[None, :] <= qpos[:, None]
            msk = jnp.broadcast_to(msk[None, None], (b, 1, qb, kb))
            msk = msk & (kpos[None, None, None, :] <
                         kv_limit[:, None, None, None])
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, qb), jnp.float32)
        a0 = jnp.zeros((b, hq, qb, hd), jnp.float32)
        # checkpoint the kv step: without it the scan VJP stacks the (qb,kb)
        # probability blocks for every step — the full S^2 score matrix
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step),
                                      (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,hq,qb,hd)
        return None, out.transpose(0, 2, 1, 3)             # (b,qb,hq,hd)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))    # (nq,b,qb,hq,hd)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, hq, hd)
    return out[:, :sq].astype(q.dtype)


def flash_attention_blocked_skip(q, k, v, *, q_offset: int = 0, kv_len=None,
                                 q_block: int = 2048, kv_block: int = 2048,
                                 scale: Optional[float] = None):
    """Causal blocked attention that SKIPS fully-masked KV blocks: each q
    block only scans kv blocks up to its own end, halving score FLOPs vs
    the masked-full baseline (EXPERIMENTS.md §Perf it.4). The q-block loop
    is a Python loop (static per-block KV extents)."""
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qb = min(q_block, sq)
    kb = min(kv_block, sk)
    sq_p = -(-sq // qb) * qb
    sk_p = -(-sk // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    nq = sq_p // qb
    kblocks = kp.reshape(b, sk_p // kb, kb, k.shape[2], hd)
    vblocks = vp.reshape(b, sk_p // kb, kb, k.shape[2], hd)
    kv_limit = kv_len if kv_len is not None else jnp.full((b,), sk, jnp.int32)

    outs = []
    for qi in range(nq):
        qblk = qp[:, qi * qb:(qi + 1) * qb].astype(jnp.float32)
        qpos = qi * qb + jnp.arange(qb) + q_offset
        n_kv = min(-(-((qi + 1) * qb + q_offset) // kb), sk_p // kb)

        def kv_step(carry, ki, qblk=qblk, qpos=qpos):
            m, l, acc = carry
            kblk = _gqa_expand(kblocks[:, ki], hq).astype(jnp.float32)
            vblk = _gqa_expand(vblocks[:, ki], hq).astype(jnp.float32)
            kpos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk) * scale
            msk = (kpos[None, :] <= qpos[:, None])[None, None]
            msk = msk & (kpos[None, None, None, :] <
                         kv_limit[:, None, None, None])
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, qb), jnp.float32)
        a0 = jnp.zeros((b, hq, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(n_kv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 2, 1, 3))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV page quantization (per-row, per-KV-head, asymmetric).
# ---------------------------------------------------------------------------


def quantize_kv(x):
    """Quantize KV rows to int8 along the head_dim axis.

    x (..., Hkv, hd) float -> (q int8, scale f32 (..., Hkv), zero f32
    (..., Hkv)) with x ~= q * scale + zero. Asymmetric per-(row, head):
    zero = midrange, scale = range / 254, so the round-trip error is
    bounded by scale / 2 = range / 508 elementwise. Per-row granularity
    means decode appends never re-quantize already-written pages."""
    xf = x.astype(jnp.float32)
    mx = xf.max(axis=-1)
    mn = xf.min(axis=-1)
    zero = (mx + mn) * 0.5
    scale = jnp.maximum(mx - mn, 1e-8) / 254.0
    q = jnp.clip(jnp.round((xf - zero[..., None]) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale, zero


def dequantize_kv(q, scale, zero):
    """Inverse of :func:`quantize_kv`: (..., Hkv, hd) f32."""
    return q.astype(jnp.float32) * scale[..., None] + zero[..., None]


# ---------------------------------------------------------------------------
# Ragged-batch paged attention oracle: one flat launch over a whole
# mixed prefill-chunk + decode ScheduleBatch.
# ---------------------------------------------------------------------------


def ragged_paged_attention_reference(q, k_pages, v_pages, tables, row, pos, *,
                                     kv_quant=None,
                                     scale: Optional[float] = None):
    """Oracle for the fused ragged kernel (kernels/ragged_attention.py).

    q (T,Hq,hd) — the step's query tokens flattened across requests
    (prefill chunks of any length and decode rows side by side);
    pages (N,bs,Hkv,hd); tables (B,nb) int32 page ids; row (T,) int32
    block-table row of each token; pos (T,) int32 absolute position.
    Token t attends causally over kv positions [0, pos[t]] of its row's
    pages (its own K/V included — written before attention, as in the
    chunked-prefill path). Padded tokens (pos < 0) return exactly zero.

    ``kv_quant`` ({k_scale,k_zero,v_scale,v_zero} pools (N,bs,Hkv) f32)
    dequantizes int8 pages at load.

    Implemented as a per-token gather of the full table span followed by
    :func:`mha_reference` with ``kv_len = pos + 1`` — the tail past a
    token's span is masked to exact zeros, so the math is term-for-term
    the chunked prefill oracle's.
    """
    t, hq, hd = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    nb = tables.shape[1]
    bt = tables.astype(jnp.int32)[row]                 # (T, nb)
    idx = (bt * bs)[:, :, None] + jnp.arange(bs)[None, None, :]
    idx = idx.reshape(t, nb * bs)                      # (T, L)
    kf = k_pages.reshape(n_pages * bs, hkv, hd)[idx]   # (T, L, Hkv, hd)
    vf = v_pages.reshape(n_pages * bs, hkv, hd)[idx]
    if kv_quant is not None:
        ks = kv_quant["k_scale"].reshape(n_pages * bs, hkv)[idx]
        kz = kv_quant["k_zero"].reshape(n_pages * bs, hkv)[idx]
        vs = kv_quant["v_scale"].reshape(n_pages * bs, hkv)[idx]
        vz = kv_quant["v_zero"].reshape(n_pages * bs, hkv)[idx]
        kf = dequantize_kv(kf, ks, kz)
        vf = dequantize_kv(vf, vs, vz)
    out = mha_reference(q[:, None], kf, vf, causal=False,
                        kv_len=pos.astype(jnp.int32) + 1, scale=scale)
    # fully-masked (padded) rows come out of the softmax uniform — zero
    # them so pad rows are exactly 0, matching the kernel's l==0 guard
    live = (pos >= 0)[:, None, None].astype(out.dtype)
    return out[:, 0] * live


# ---------------------------------------------------------------------------
# Decode attention oracle: one new token per sequence against a long cache.
# ---------------------------------------------------------------------------


def decode_attention_reference(q, k_cache, v_cache, kv_len, *,
                               scale: Optional[float] = None):
    """q (B,1,Hq,hd); caches (B,S,Hkv,hd); kv_len (B,) valid lengths."""
    return mha_reference(q, k_cache, v_cache, causal=False, kv_len=kv_len,
                         scale=scale)


def paged_decode_attention_reference(q, k_pages, v_pages, block_tables,
                                     kv_len, *,
                                     scale: Optional[float] = None):
    """Blocked oracle for the paged decode kernel.

    q (B,1,Hq,hd); pages (N,bs,Hkv,hd) shared pool; block_tables (B,nb)
    int32 page ids; kv_len (B,) valid lengths. Scans the block table with
    an online softmax — the page gather is one ``jnp.take`` per step, so
    no (B, nb*bs) contiguous cache is ever materialized.
    """
    b, one, hq, hd = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q[:, 0].astype(jnp.float32)                   # (B,Hq,hd)
    tables = block_tables.astype(jnp.int32)

    def step(carry, ib):
        m, l, acc = carry
        page = tables[:, ib]                           # (B,)
        k = _gqa_expand(jnp.take(k_pages, page, axis=0), hq)
        v = _gqa_expand(jnp.take(v_pages, page, axis=0), hq)
        kpos = ib * bs + jnp.arange(bs)
        s = jnp.einsum("bhd,bkhd->bhk", qf,
                       k.astype(jnp.float32)) * scale  # (B,Hq,bs)
        mask = kpos[None, None, :] < kv_len[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhk,bkhd->bhd", p, v.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq), jnp.float32)
    a0 = jnp.zeros((b, hq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)


def decode_attention_with_stats(q, k_cache, v_cache, kv_len, *,
                                scale: Optional[float] = None):
    """Decode attention that also returns the softmax stats (m, l) so a new
    token's contribution can be combined without writing it to the cache
    first (flash-decoding append-combine; §Perf it.5).
    Returns (out (B,1,Hq,hd) f32, m (B,Hq) f32, l (B,Hq) f32)."""
    b, one, hq, hd = q.shape
    sk = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kx = _gqa_expand(k_cache, hq).astype(jnp.float32)
    vx = _gqa_expand(v_cache, hq).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx) * scale
    valid = jnp.arange(sk)[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(-1)[..., 0]                                  # (B,Hq)
    p = jnp.where(valid, jnp.exp(s - m[..., None, None]), 0.0)
    l = p.sum(-1)[..., 0]                                  # (B,Hq)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx)             # unnormalized
    return out, m, l


# ---------------------------------------------------------------------------
# WKV6 (RWKV6 'Finch') recurrence oracle.
#   state S (B,H,hd,hd);   y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
#   S_t = diag(w_t) S_{t-1} + k_t v_t^T            (w_t data-dependent decay)
# ---------------------------------------------------------------------------


def wkv6_reference(r, k, v, w, u, initial_state=None):
    """r,k,v,w: (B,T,H,hd); u: (H,hd). Returns (y (B,T,H,hd), final_state)."""
    b, t, h, n = r.shape
    f32 = jnp.float32
    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, n), f32)

    def step(S, xs):
        rt, kt, vt, wt = xs                                # (b,h,n) each
        kv = kt[..., :, None] * vt[..., None, :]           # (b,h,n,n)
        St = S + u[None, :, :, None] * kv
        # y[j] = sum_i r[i] * St[i,j]
        y = jnp.einsum("bhi,bhij->bhj", rt, St)
        S_new = jnp.exp(-jnp.exp(wt))[..., None] * S + kv
        return S_new, y

    xs = tuple(x.astype(f32).transpose(1, 0, 2, 3) for x in (r, k, v, w))
    S, ys = jax.lax.scan(step, initial_state, xs)
    y = ys.transpose(1, 0, 2, 3)                           # (b,t,h,n)
    return y.astype(r.dtype), S


def wkv6_chunked(r, k, v, w, u, initial_state=None, chunk: int = 64):
    """Same recurrence, outer scan over chunks with checkpointed inner scan
    so training memory is O(T/chunk) states instead of O(T)."""
    b, t, h, n = r.shape
    if t <= chunk:
        return wkv6_reference(r, k, v, w, u, initial_state)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, n), jnp.float32)
    pad = (-t) % chunk
    if pad:
        r, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for x in (r, k, v))
        # padded steps must not decay the state: w -> -inf gives decay 1
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=-1e9)
    tc = (t + pad) // chunk

    def resh(x):
        return (x.astype(jnp.float32)
                .reshape(b, tc, chunk, h, n).transpose(1, 0, 2, 3, 4))

    def outer(S, xs):
        rc, kc, vc, wc = xs
        y, S_new = jax.checkpoint(
            lambda S0, a: wkv6_reference(a[0], a[1], a[2], a[3], u, S0)
        )(S, (rc, kc, vc, wc))
        return S_new, y

    S, ys = jax.lax.scan(outer, initial_state,
                         (resh(r), resh(k), resh(v), resh(w)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, tc * chunk, h, n)[:, :t]
    return y.astype(r.dtype), S
