"""Synthetic token pipeline: deterministic, shardable, no I/O dependency.
Produces batches shaped like the assigned train shapes; real deployments
would swap in a tokenized corpus reader behind the same iterator API."""

from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticTokens:
    """Zipf-distributed token stream with a fixed seed; yields dicts matching
    Model.input_structs."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        ranks = self.rng.zipf(1.3, size=(self.batch, self.seq))
        tokens = np.minimum(ranks, cfg.vocab - 1).astype(np.int32)
        out = {"tokens": tokens}
        if cfg.family == "vlm":
            out["patch_embeds"] = self.rng.standard_normal(
                (self.batch, cfg.n_image_tokens, cfg.d_model)).astype(
                np.float32) * 0.02
        if cfg.is_encdec:
            out["frames"] = self.rng.standard_normal(
                (self.batch, cfg.n_audio_frames, cfg.d_model)).astype(
                np.float32) * 0.02
        return out
