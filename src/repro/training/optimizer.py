"""AdamW with fp32 state, optional ZeRO-1 (optimizer-state sharding over the
data axis) and bf16 gradient compression."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import resolve
from repro.models.common import ParamDef, map_defs


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_state(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_structs(param_structs):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, param_structs),
        "nu": jax.tree.map(f32, param_structs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_specs(defs, zero1: bool = True):
    """Optimizer-state PartitionSpecs. ZeRO-1: each state additionally
    shards its first *physically replicated* dim over the data(+pod) axes.
    Input shardings must divide evenly, so only dims divisible by 32 (data x
    pod on the multi-pod mesh) qualify."""
    from jax.sharding import PartitionSpec as P

    def spec(d: ParamDef):
        base = resolve(d.axes)
        parts = list(base) + [None] * (len(d.shape) - len(base))
        if zero1:
            used = set()
            for part in parts:
                if part is None:
                    continue
                used.update((part,) if isinstance(part, str) else part)
            if "data" not in used:
                for i, (part, dim) in enumerate(zip(parts, d.shape)):
                    if part is None and dim >= 32 and dim % 32 == 0:
                        parts[i] = ("pod", "data")
                        break
        return P(*parts)

    ps = map_defs(spec, defs)
    return {"mu": ps, "nu": ps, "step": P()}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def apply_updates(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = _schedule(cfg, step)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step)
        nu_hat = nu / (1 - cfg.b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (delta + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
