"""Train step: value_and_grad over Model.loss, bf16 gradient compression,
AdamW update. ``make_train_step`` returns the function the dry-run lowers."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training import optimizer as opt


def make_train_step(model: Model, cfg: opt.AdamWConfig = opt.AdamWConfig(),
                    remat: str = "dots", grad_dtype: Optional[str] = "bfloat16"):
    def train_step(params, state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        if grad_dtype is not None:
            # gradient compression: cross-replica reduction happens in bf16
            gd = jnp.dtype(grad_dtype)
            grads = jax.tree.map(lambda g: g.astype(gd), grads)
        params, state, om = opt.apply_updates(cfg, params, grads, state)
        metrics = dict(metrics, loss=loss, **om)
        return params, state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return dict(metrics, loss=loss)
    return eval_step
