"""Fault-tolerant checkpointing: atomic commit (write temp dir + manifest +
rename), keep-last-k retention, restore-latest. Pytree leaves are stored as
individual .npy files keyed by their tree path."""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import urllib.parse
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def encode_key(key: str) -> str:
    """Collision-free, filename-safe encoding of a tree-path key.

    The old ``key.replace("/", "__")`` collided for leaf keys that
    themselves contain ``__`` (``{"a__b": x}`` vs ``{"a": {"b": y}}`` both
    mapped to ``a__b``, silently overwriting one leaf's file with the
    other's). Percent-encoding is injective — ``%`` itself is always
    escaped — so distinct keys always get distinct file names. Restore
    never needs a decoder: manifests record the original key next to the
    encoded file name."""
    return urllib.parse.quote(key, safe="")


def fsync_dir(path: str):
    """fsync a directory so a just-committed rename survives a crash.
    Without this the directory entry for an ``os.rename`` commit can
    still be lost on power failure even though the file contents were
    fsynced. Best-effort on platforms that refuse O_RDONLY on dirs."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                      # e.g. Windows
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # --------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        leaves = _flatten_with_paths(tree)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp-")
        manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
        try:
            for key, leaf in leaves.items():
                arr = np.asarray(leaf)
                fname = encode_key(key) + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {"key": key, "file": fname, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic commit...
            fsync_dir(self.dir)          # ...durable only once the parent
        except BaseException:            #    directory entry is on disk
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                # only committed checkpoints (manifest present) count
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None):
        """Restore into the structure of `template` (values replaced)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            entry = by_key[key]
            arr = np.load(os.path.join(d, entry["file"]))
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest
