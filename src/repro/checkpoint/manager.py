"""Fault-tolerant checkpointing: atomic commit (write temp dir + manifest +
rename), keep-last-k retention, restore-latest. Pytree leaves are stored as
individual .npy files keyed by their tree path."""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # --------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        leaves = _flatten_with_paths(tree)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp-")
        manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
        try:
            for key, leaf in leaves.items():
                arr = np.asarray(leaf)
                fname = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {"key": key, "file": fname, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic commit
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                # only committed checkpoints (manifest present) count
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None):
        """Restore into the structure of `template` (values replaced)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            entry = by_key[key]
            arr = np.load(os.path.join(d, entry["file"]))
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest
