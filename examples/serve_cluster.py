"""End-to-end serverless serving driver: Azure-like bursty traffic over the
paper's testbed, comparing serverless vLLM, ServerlessLLM and HydraServe —
plus HydraServe under the proactive fleet policy (Alg. 1 model
distribution + predictive prewarming + delayed downscale) — including a
mid-run worker failure with cold-start recovery. Testbed and profiles are
the shared benchmark definitions (benchmarks/common.py); every system row
runs through the same ``FleetController`` policy core.

    PYTHONPATH=src python examples/serve_cluster.py [--rps 0.6] [--cv 8]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import profiles, testbed_i
from repro.fleet.controller import FleetPolicy
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import generate, make_instances

SYSTEMS = [
    ("vllm", "vllm", None),
    ("serverlessllm", "serverlessllm", None),
    ("hydra", "hydra", None),
    ("hydra+fleet", "hydra", FleetPolicy.proactive(
        keepalive_s=300.0, placement_interval_s=30.0, placement_top_k=8)),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=0.6)
    ap.add_argument("--cv", type=float, default=8.0)
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--instances", type=int, default=64)
    args = ap.parse_args()

    print(f"{'system':16s} {'n':>5s} {'ttft_att':>9s} {'tpot_att':>9s} "
          f"{'mean_ttft':>10s} {'p99':>7s} {'colds':>6s} {'prewarm':>8s}")
    for label, system, policy in SYSTEMS:
        insts = make_instances(APPLICATIONS, args.instances)
        sim = ServerlessSim(testbed_i(), profiles(), insts, system=system,
                            policy=policy)
        reqs = generate(insts, rps=args.rps, cv=args.cv,
                        duration=args.duration, seed=0)
        sim.submit(reqs)
        # inject a worker failure mid-run: recovery is a fresh cold start
        sim.sim.at(args.duration / 2,
                   lambda s=sim, i=insts: s.inject_failure(i[0].name))
        sim.run(until=args.duration * 6)
        m = sim.metrics()
        print(f"{label:16s} {m['n']:5d} {m['ttft_attainment']:9.3f} "
              f"{m['tpot_attainment']:9.3f} {m['ttft_mean']:10.2f} "
              f"{m['ttft_p99']:7.1f} {m['cold_starts']:6d} "
              f"{m['prewarms']:8d}")


if __name__ == "__main__":
    main()
