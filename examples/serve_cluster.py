"""End-to-end serverless serving driver: Azure-like bursty traffic over the
paper's testbed, comparing serverless vLLM, ServerlessLLM and HydraServe,
including a mid-run worker failure with cold-start recovery.

    PYTHONPATH=src python examples/serve_cluster.py [--rps 0.6] [--cv 8]
"""

import argparse

from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import (APPLICATIONS, WARM,
                                          kv_bytes_for, timings_for)
from repro.workloads.generator import generate, make_instances


def testbed():
    servers = [ServerSpec(f"a10-{i}", 16 * Gbps, 12e9, 24 * GB, 1)
               for i in range(4)]
    servers += [ServerSpec(f"v100-{i}", 16 * Gbps, 12e9, 32 * GB, 4)
                for i in range(4)]
    return servers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=0.6)
    ap.add_argument("--cv", type=float, default=8.0)
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--instances", type=int, default=64)
    args = ap.parse_args()

    profiles = {n: ModelProfile(n, w.size_bytes, timings_for(n),
                                SLO(7.5, 0.2),
                                kv_bytes_per_token=kv_bytes_for(n))
                for n, w in WARM.items()}
    print(f"{'system':16s} {'n':>5s} {'ttft_att':>9s} {'tpot_att':>9s} "
          f"{'mean_ttft':>10s} {'p99':>7s} {'colds':>6s}")
    for system in ("vllm", "serverlessllm", "hydra"):
        insts = make_instances(APPLICATIONS, args.instances)
        sim = ServerlessSim(testbed(), profiles, insts, system=system)
        reqs = generate(insts, rps=args.rps, cv=args.cv,
                        duration=args.duration, seed=0)
        sim.submit(reqs)
        # inject a worker failure mid-run: recovery is a fresh cold start
        sim.sim.at(args.duration / 2,
                   lambda s=sim, i=insts: s.inject_failure(i[0].name))
        sim.run(until=args.duration * 6)
        m = sim.metrics()
        print(f"{system:16s} {m['n']:5d} {m['ttft_attainment']:9.3f} "
              f"{m['tpot_attainment']:9.3f} {m['ttft_mean']:10.2f} "
              f"{m['ttft_p99']:7.1f} {m['cold_starts']:6d}")


if __name__ == "__main__":
    main()
