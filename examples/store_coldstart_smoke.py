"""CI smoke: cold-start a tiny model through the on-disk ModelStore.

Deploys a smoke model into a real chunked store on disk, cold-starts a
pipeline group whose stage weights are *streamed* out of it, serves a
few greedy tokens, and verifies bit-exactness against an in-memory
engine built from the same params. The measured per-stage timeline —
plus the measured-vs-analytic cross-check for every OverlapFlags
ablation step — is written to ``BENCH_coldstart_timeline.json`` (CI
uploads it next to ``BENCH_engine.json``).

    PYTHONPATH=src python examples/store_coldstart_smoke.py
"""

import json
import tempfile

import jax

from repro.configs import get_config, smoke_variant
from repro.core import GB, ModelProfile, SLO, ServerSpec, TimingProfile
from repro.core.coldstart import OverlapFlags
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServerlessFrontend, ServingEndpoint
from repro.serving.engine import Engine
from repro.store import assert_within, crosscheck_stages

cfg = smoke_variant(get_config("granite-3-8b"))
params = build_model(cfg).init(jax.random.PRNGKey(0))

store_dir = tempfile.mkdtemp(prefix="store-smoke-")
front = ServerlessFrontend({f"srv{i}": ServerSpec(f"srv{i}", 2e9, 12e9,
                                                  24 * GB)
                            for i in range(4)})
store = front.deploy(cfg, params, ModelProfile(
    cfg.name, int(12.5 * GB), TimingProfile(), SLO(ttft=7.5, tpot=0.2)),
    store_dir=store_dir)
print(f"store: {store.total_bytes} bytes in "
      f"{len(store.manifest.chunks)} chunks at {store_dir}")

ep = front.cold_start(cfg.name, min_stages=2, max_batch=2, max_seq=64)
report = ep.cold_start_timeline
print(f"cold start: s={ep.n_stages}, streamed {report.total_bytes} bytes, "
      f"measured ready={report.ready:.3f}s")

prompt = [11, 42, 7, 13, 5]
tokens = [ev.token for ev in ep.generate(prompt, SamplingParams(max_new=8))]
ref = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64))
want = [ev.token for ev in ref.generate(prompt, SamplingParams(max_new=8))]
assert tokens == want, f"store-streamed weights diverged: {tokens} != {want}"
print(f"OK: first {len(tokens)} greedy tokens bit-exact with the "
      f"in-memory engine: {tokens}")

# measured-vs-analytic cross-check over the Fig. 9 ablation axis
nic = store.total_bytes / 8.0
ablation = {}
for name, flags in [("none", OverlapFlags.none()),
                    ("+prefetch", OverlapFlags(True, False, False)),
                    ("+stream", OverlapFlags(True, True, False)),
                    ("+overlap", OverlapFlags.all())]:
    checks = crosscheck_stages(store, min(2, cfg.n_periods), flags=flags,
                               nic_bytes_per_s=nic, load_bytes_per_s=4 * nic)
    worst = assert_within(checks, 0.05)
    ablation[name] = {"worst_err": worst,
                      "stages": [c.to_json() for c in checks]}
    print(f"  {name:10s} measured==analytic within {worst:.2%}")

with open("BENCH_coldstart_timeline.json", "w") as f:
    json.dump({"model": cfg.name, "store_bytes": store.total_bytes,
               "cold_start": report.to_json(),
               "tokens_bit_exact": True,
               "ablation_crosscheck": ablation}, f, indent=2)
print("wrote BENCH_coldstart_timeline.json")
