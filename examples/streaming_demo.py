"""Streaming + sampling demo of the request-lifecycle API.

Three concurrent requests with different decode policies — greedy,
seeded temperature/top-k sampling, and a stop-token request — stream
token events out of one engine step loop. Shows per-request finish
reasons and step-metrics at the end.

    PYTHONPATH=src python examples/streaming_demo.py
"""

import jax

from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine

cfg = smoke_variant(get_config("granite-3-8b"))
params = build_model(cfg).init(jax.random.PRNGKey(0))
ep = ServingEndpoint(Engine(cfg, [params], max_batch=4, max_seq=64))

# learn the greedy stream once so the stop-token demo is guaranteed to hit
probe = [ev.token for ev in ep.generate([5, 7, 9, 11],
                                        SamplingParams(max_new=8))]

reqs = {
    "greedy ": ep.submit([5, 7, 9, 11], SamplingParams(max_new=8)),
    "sampled": ep.submit([5, 7, 9, 11],
                         SamplingParams(max_new=8, temperature=0.8,
                                        top_k=8, seed=1234)),
    "stopped": ep.submit([5, 7, 9, 11],
                         SamplingParams(max_new=8,
                                        stop_tokens=(probe[3],))),
}
stop_at = probe.index(probe[3])          # stop fires at first occurrence
while ep.has_work():
    out = ep.step()
    for ev in out.events:
        fin = f"  <- {ev.finish_reason.value}" if ev.finish_reason else ""
        print(f"step {out.step}: rid={ev.rid} token={ev.token}{fin}")

for name, r in reqs.items():
    m = r.metrics
    print(f"{name}: {r.generated} finish={r.finish_reason.value} "
          f"ttft={m.ttft_steps} queue={m.queue_steps} "
          f"decode_steps={m.decode_steps}")

assert reqs["greedy "].generated == probe
assert reqs["stopped"].generated == probe[:stop_at + 1]
assert reqs["sampled"].generated != probe
print("OK: streaming order, stop-token truncation, and sampling diverge "
      "as expected")
