"""Train a ~100M-param dense LM for a few hundred steps on CPU with the
full production train_step (AdamW + ZeRO-1 specs + remat + checkpointing),
demonstrating fault-tolerant restart.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import dataclasses
import os
import shutil
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.training import optimizer as opt
from repro.training.data import SyntheticTokens
from repro.training.train_step import make_train_step

CKPT_DIR = "/tmp/repro_train_small"


def small_config():
    return ModelConfig(
        name="demo-100m", family="dense", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab=8192, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.fresh and os.path.exists(CKPT_DIR):
        shutil.rmtree(CKPT_DIR)

    cfg = small_config()
    model = build_model(cfg)
    print(f"params: {model.bytes()/4/1e6:.1f}M")
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params)

    ckpt = CheckpointManager(CKPT_DIR, keep=2)
    start = 0
    if ckpt.latest_step() is not None:
        (params, state), manifest = ckpt.restore((params, state))
        start = manifest["step"]
        print(f"restored checkpoint at step {start} (fault-tolerant resume)")

    step_fn = jax.jit(make_train_step(
        model, opt.AdamWConfig(lr=1e-3, warmup_steps=20,
                               total_steps=args.steps),
        remat="none", grad_dtype=None))
    data = iter(SyntheticTokens(cfg, args.batch, args.seq, seed=1))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(data)
        params, state, metrics = step_fn(params, state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if step and step % 50 == 0:
            path = ckpt.save(step, (params, state))
            print(f"  checkpoint -> {path}")
    ckpt.save(args.steps, (params, state))
    print("done; rerun without --fresh to resume from the last checkpoint")


if __name__ == "__main__":
    main()
