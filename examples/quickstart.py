"""Quickstart: hydra cold start end-to-end on CPU.

1. 'Upload' a small model to the registry (reduced granite config).
2. The controller picks a pipeline-parallel cold-start scheme (Alg. 1).
3. Stage workers fetch their slices and serve a request as a pipeline.
4. Pipeline consolidation (scale-down) migrates the KV cache to one
   standalone worker mid-generation — tokens must be unchanged.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_config, smoke_variant
from repro.core import (GB, Gbps, CentralController, ModelProfile,
                        ServerSpec, SLO, TimingProfile)
from repro.models import build_model
from repro.serving.engine import Engine

# --- 1. registry ---------------------------------------------------------
cfg = smoke_variant(get_config("granite-3-8b"))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"model: {cfg.name}  ({model.bytes()/1e6:.1f} MB synthetic weights)")

# --- 2. cluster-level planning (Alg. 1 + Alg. 2) -------------------------
servers = {f"srv{i}": ServerSpec(f"srv{i}", 16 * Gbps, 12e9, 24 * GB)
           for i in range(4)}
controller = CentralController(servers)
controller.register_model(ModelProfile(
    cfg.name, int(12.5 * GB),            # pretend it's the real Llama2-7B
    TimingProfile(), SLO(ttft=7.5, tpot=0.2)))
scheme = controller.plan_cold_start(cfg.name,
                                    {s: 24 * GB for s in servers}, now=0.0)
print(f"Alg.1 scheme: s={scheme.s} w={scheme.w} servers={scheme.servers} "
      f"pred_ttft={scheme.predicted_ttft:.2f}s "
      f"pred_tpot={scheme.predicted_tpot*1e3:.0f}ms slo_ok={scheme.slo_ok}")

# --- 3. pipeline-parallel serving ----------------------------------------
n_stages = max(scheme.s, 2)
stage_params = [model.slice_stage_params(params, n_stages, i)
                for i in range(n_stages)]
for i in range(n_stages):
    print(f"  stage {i}: fetches {model.stage_bytes(n_stages, i)/1e6:.1f} MB")
eng = Engine(cfg, stage_params, max_batch=2, max_seq=64)
req = eng.submit([11, 42, 7, 13, 5], max_new=12)

# --- 4. consolidation mid-generation -------------------------------------
for _ in range(5):
    eng.step()
print(f"tokens before consolidation: {req.generated}")
eng = eng.consolidated(params)        # KV gather -> standalone worker
eng.run()
print(f"tokens after consolidation:  {req.generated}")

ref = Engine(cfg, [params], max_batch=2, max_seq=64)
rref = ref.submit([11, 42, 7, 13, 5], max_new=12)
ref.run()
assert rref.generated == req.generated, "consolidation changed the output!"
print("OK: pipeline + consolidation output == single-worker reference")
