"""Quickstart: hydra cold start end-to-end on CPU, against one API.

The ServerlessFrontend runs Alg. 1 and hands back a ServingEndpoint; the
endpoint serves, then consolidates (§6.2) behind the same handle — the
client never sees the pipeline group dissolve.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_config, smoke_variant
from repro.core import GB, Gbps, ModelProfile, ServerSpec, SLO, TimingProfile
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServerlessFrontend, ServingEndpoint
from repro.serving.engine import Engine

cfg = smoke_variant(get_config("granite-3-8b"))
params = build_model(cfg).init(jax.random.PRNGKey(0))

front = ServerlessFrontend({f"srv{i}": ServerSpec(f"srv{i}", 16 * Gbps,
                                                  12e9, 24 * GB)
                            for i in range(4)})
front.deploy(cfg, params, ModelProfile(
    cfg.name, int(12.5 * GB),            # pretend it's the real Llama2-7B
    TimingProfile(), SLO(ttft=7.5, tpot=0.2)))

ep = front.cold_start(cfg.name, min_stages=2, max_batch=2, max_seq=64)
print(f"Alg.1 scheme: s={ep.scheme.s} w={ep.scheme.w} "
      f"servers={ep.scheme.servers} -> {ep.n_stages}-stage pipeline, "
      f"pred_ttft={ep.scheme.predicted_ttft:.2f}s slo_ok={ep.scheme.slo_ok}")

req = ep.submit([11, 42, 7, 13, 5], SamplingParams(max_new=12))
for _ in range(5):
    ep.step()
print(f"tokens before consolidation: {req.generated}")
ep.consolidate(front.full_params(cfg.name))   # §6.2, same handle
ep.run()
print(f"tokens after consolidation:  {req.generated} "
      f"({req.finish_reason.value}, ttft={req.metrics.ttft_steps} steps)")

ref = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64))
tokens = [ev.token for ev in ref.generate([11, 42, 7, 13, 5],
                                          SamplingParams(max_new=12))]
assert tokens == req.generated, "consolidation changed the output!"
print("OK: endpoint output == single-worker reference across consolidation")
