"""Pipeline-consolidation deep dive (paper §6, Fig. 13): serve one long
generation on a 4-stage pipeline, scale DOWN mid-flight, and show the
per-token latency profile before/after the KV migration. Uses the real JAX
endpoint API (reduced-config jamba — the hybrid arch migrates attention KV
*and* Mamba/conv recurrent state), and the swap happens behind the stable
ServingEndpoint handle.

    PYTHONPATH=src python examples/consolidation_demo.py
"""

import time

import jax

from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine
from repro.serving.migration import gather_stage_caches

cfg = smoke_variant(get_config("jamba-v0.1-52b"))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

n_stages = 4 if cfg.n_periods >= 4 else 2
stage_params = [model.slice_stage_params(params, n_stages, i)
                for i in range(n_stages)]
print(f"{cfg.name}: {cfg.n_layers} layers in {n_stages} stages; per-stage "
      f"fetch bytes: {[model.stage_bytes(n_stages, i) for i in range(n_stages)]}")

ep = ServingEndpoint(Engine(cfg, stage_params, max_batch=2, max_seq=96))
req = ep.submit(list(range(2, 18)), SamplingParams(max_new=24))

lat = []
for step in range(8):
    t0 = time.perf_counter()
    ep.step()
    lat.append(time.perf_counter() - t0)
print(f"pipeline tokens: {req.generated}")
print(f"pipeline per-step wall: {[f'{x*1e3:.0f}ms' for x in lat]}")

t0 = time.perf_counter()
gathered = gather_stage_caches([w.cache for w in ep.engine.workers])
mig_wall = time.perf_counter() - t0
n_bytes = sum(x.nbytes for x in jax.tree.leaves(gathered))
print(f"KV+state migration: {n_bytes/1e6:.2f} MB gathered in "
      f"{mig_wall*1e3:.1f} ms (host)")

ep.consolidate(params)                   # same handle, standalone engine
lat2 = []
while req.generated and not req.done:
    t0 = time.perf_counter()
    ep.step()
    lat2.append(time.perf_counter() - t0)
    if len(lat2) > 40:
        break
print(f"standalone tokens: {req.generated}")
print(f"standalone per-step wall: {[f'{x*1e3:.0f}ms' for x in lat2[:8]]}")

# correctness: the full run must equal a never-pipelined run
ref = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=96))
r2 = ref.submit(list(range(2, 18)), SamplingParams(max_new=24))
ref.run()
assert r2.generated == req.generated
print("OK: scale-down preserved the generation exactly "
      f"(ttft={req.metrics.ttft_steps} steps, "
      f"tpot-proxy={req.metrics.tpot_steps:.2f} steps/token)")
