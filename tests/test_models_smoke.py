"""Per-arch smoke tests: reduced config of the same family, one forward /
train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from conftest import ALL_ARCHS, smoke
from repro.models import build_model


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name, rng):
    cfg = smoke(name)
    m = build_model(cfg)
    params = m.init(rng)
    batch = m.dummy_inputs(rng, batch=2, seq=16)
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (name, loss)
    assert jnp.isfinite(metrics["ce"])


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_smoke(name, rng):
    cfg = smoke(name)
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 2, 12
    batch = m.dummy_inputs(rng, batch=B, seq=S)
    logits, cache = m.prefill(params, batch, max_seq=S + 8)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits)), name
    plen = cfg.n_image_tokens if cfg.family == "vlm" else 0
    pos = jnp.full((B, 1), plen + S, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, cache2 = m.decode_step(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits2)), name
    # padded vocab entries must never win the argmax
    assert int(jnp.max(jnp.argmax(logits2, -1))) < cfg.vocab


@pytest.mark.parametrize("name", ["granite-3-8b", "jamba-v0.1-52b",
                                  "rwkv6-1.6b", "whisper-small"])
def test_grad_flows(name, rng):
    cfg = smoke(name)
    m = build_model(cfg)
    params = m.init(rng)
    batch = m.dummy_inputs(rng, batch=2, seq=8)
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    # at least 90% of leaves get nonzero gradient signal
    nonzero = sum(bool(jnp.any(g != 0)) for g in flat)
    assert nonzero >= 0.9 * len(flat), (name, nonzero, len(flat))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_stage_slicing_covers_params(name, rng):
    """Pipeline stage defs partition the blocks and assign embed/head."""
    cfg = smoke(name)
    m = build_model(cfg)
    full_bytes = m.bytes()
    for s in (1, 2):
        if cfg.n_periods < s:
            continue
        total = sum(m.stage_bytes(s, i) for i in range(s))
        assert total == full_bytes, (name, s, total, full_bytes)
