"""Fused ragged-batch paged attention: parity of the blocked reference
and the Pallas kernel (interpret mode) against the per-request oracle,
across the decode/chunk/mixed x history x GQA matrix, plus the int8
quantized-KV round-trip and accuracy bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ragged_attention import ragged_paged_attention

BS = 8           # page size
HD = 16


def _build(specs, hkv, hq, seed=0, tile_q=8):
    """specs: per request (hist, new) — history rows already in the pool,
    `new` query tokens at positions [hist, hist+new). Returns the flat
    ragged batch plus the dense per-request views for the oracle. All
    hist+new rows are pre-written into the pool (the model writes K/V
    before attending)."""
    rng = np.random.RandomState(seed)
    nreq = len(specs)
    max_len = max(h + n for h, n in specs)
    nb = -(-max_len // BS) + 1
    n_pages = nreq * nb + 1                   # +1 trash page
    k_pages = rng.randn(n_pages, BS, hkv, HD).astype(np.float32)
    v_pages = rng.randn(n_pages, BS, hkv, HD).astype(np.float32)
    tables = np.arange(nreq * nb, dtype=np.int32).reshape(nreq, nb)

    def dense(pages, r, n):                   # rows [0, n) of request r
        flat = pages.reshape(-1, hkv, HD)
        idx = tables[r, np.arange(n) // BS] * BS + np.arange(n) % BS
        return flat[idx]

    q_rows, rows, poss, spans = [], [], [], []
    for r, (hist, new) in enumerate(specs):
        na = -(-new // tile_q) * tile_q
        spans.append((len(rows), new))
        q_rows.append(rng.randn(na, hq, HD).astype(np.float32))
        rows.extend([r] * na)
        poss.extend(range(hist, hist + new))
        poss.extend([-1] * (na - new))
    q = np.concatenate(q_rows, axis=0)
    return (jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(tables), jnp.asarray(np.asarray(rows, np.int32)),
            jnp.asarray(np.asarray(poss, np.int32)), spans, dense)


def _oracle(q, dense_k, dense_v, spans, specs):
    """Per-request full-softmax oracle: causal attention of the new
    tokens over [0, hist+new) with q_offset=hist."""
    outs = jnp.zeros_like(q)
    for r, (hist, new) in enumerate(specs):
        start, _ = spans[r]
        kf = dense_k(r, hist + new)[None]
        vf = dense_v(r, hist + new)[None]
        o = ref.mha_reference(q[start:start + new][None], kf, vf,
                              causal=True, q_offset=hist)
        outs = outs.at[start:start + new].set(o[0])
    return outs


MATRIX = [
    ("decode-only", [(9, 1), (17, 1), (3, 1)]),
    ("decode-hist0", [(0, 1), (0, 1)]),
    ("chunk-only", [(0, 8), (0, 13)]),
    ("chunk-hist", [(8, 8), (16, 5)]),
    ("mixed", [(9, 1), (0, 11), (24, 1), (8, 8)]),
]


@pytest.mark.parametrize("name,specs", MATRIX, ids=[m[0] for m in MATRIX])
@pytest.mark.parametrize("group", [1, 2], ids=["mha", "gqa2"])
def test_ragged_reference_matches_oracle(name, specs, group):
    hkv = 2
    q, kp, vp, tables, row, pos, spans, dense = _build(specs, hkv,
                                                       hkv * group)
    dk = lambda r, n: dense(np.asarray(kp), r, n)
    dv = lambda r, n: dense(np.asarray(vp), r, n)
    want = _oracle(q, dk, dv, spans, specs)
    got = ref.ragged_paged_attention_reference(q, kp, vp, tables, row, pos)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    # pad rows are exactly zero
    pad = np.asarray(pos) < 0
    assert np.all(np.asarray(got)[pad] == 0.0)


@pytest.mark.parametrize("name,specs", MATRIX, ids=[m[0] for m in MATRIX])
def test_ragged_kernel_interpret_matches_reference(name, specs):
    q, kp, vp, tables, row, pos, _, _ = _build(specs, 2, 4)
    want = ref.ragged_paged_attention_reference(q, kp, vp, tables, row, pos)
    got = ragged_paged_attention(q, kp, vp, tables, row, pos,
                                 interpret=True)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_ragged_kernel_interpret_int8():
    specs = [(9, 1), (0, 11), (24, 1), (8, 8)]
    q, kp, vp, tables, row, pos, _, _ = _build(specs, 2, 4)
    kq, ks, kz = ref.quantize_kv(kp)
    vq, vs, vz = ref.quantize_kv(vp)
    kvq = {"k_scale": ks, "k_zero": kz, "v_scale": vs, "v_zero": vz}
    want = ref.ragged_paged_attention_reference(q, kq, vq, tables, row,
                                                pos, kv_quant=kvq)
    got = ragged_paged_attention(q, kq, vq, tables, row, pos,
                                 kv_quant=kvq, interpret=True)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    # int8 storage stays close to the fp result: attention is a convex
    # combination of V rows, so the output error is bounded by the
    # dequant error of K (via logits) and V
    fp = ref.ragged_paged_attention_reference(q, kp, vp, tables, row, pos)
    assert float(jnp.max(jnp.abs(want - fp))) < 0.15


def test_int8_roundtrip_bound():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(32, 8, 2, HD).astype(np.float32) * 3.0)
    q, scale, zero = ref.quantize_kv(x)
    back = ref.dequantize_kv(q, scale, zero)
    err = jnp.abs(back - x)
    # asymmetric per-row quant: |err| <= scale/2 (+ rounding eps)
    bound = scale[..., None] * 0.5 + 1e-6
    assert bool(jnp.all(err <= bound))
    # scale/zero shapes drop the head_dim axis only
    assert scale.shape == x.shape[:-1] and zero.shape == x.shape[:-1]
    assert q.dtype == jnp.int8


def test_ragged_kernel_tile4():
    # tile_q is a host knob: a smaller tile must not change results
    specs = [(5, 1), (0, 6)]
    q, kp, vp, tables, row, pos, _, _ = _build(specs, 2, 4, tile_q=4)
    want = ref.ragged_paged_attention_reference(q, kp, vp, tables, row, pos)
    got = ragged_paged_attention(q, kp, vp, tables, row, pos, tile_q=4,
                                 interpret=True)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
