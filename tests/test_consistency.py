"""Prefill+decode must equal the full forward pass (KV-cache correctness),
for every mixer family."""

import jax
import jax.numpy as jnp
import pytest

from conftest import smoke
from repro.models import build_model
from repro.models import encdec, transformer

FAMS = ["granite-3-8b", "qwen1.5-32b", "qwen2-moe-a2.7b", "jamba-v0.1-52b",
        "rwkv6-1.6b", "whisper-small", "llava-next-34b"]


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_full_forward(name, rng):
    cfg = smoke(name)
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 2, 10
    batch = m.dummy_inputs(rng, batch=B, seq=S + 1)
    toks = batch["tokens"]

    if cfg.is_encdec:
        memory = encdec.encode(cfg, params, batch["frames"])
        pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
        h, _ = encdec.decoder(cfg, params, toks, pos, memory=memory)
        logits_full = encdec.head(cfg, params, h)[:, S]
        plen = 0
    else:
        prefix = batch.get("patch_embeds")
        plen = prefix.shape[1] if prefix is not None else 0
        pos = jnp.broadcast_to(jnp.arange(plen + S + 1)[None],
                               (B, plen + S + 1))
        x = transformer.embed(cfg, params, toks, pos, prefix_embeds=prefix)
        x, _, _ = transformer.run_blocks(cfg, params["blocks"], x, pos)
        logits_full = transformer.head(cfg, params, x)[:, plen + S]

    pre = dict(batch)
    pre["tokens"] = toks[:, :S]
    _, cache = m.prefill(params, pre, max_seq=plen + S + 4)
    logits_dec, _ = m.decode_step(params, cache, toks[:, S:S + 1],
                                  jnp.full((B, 1), plen + S, jnp.int32))
    scale = float(jnp.max(jnp.abs(logits_full)))
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 2e-3 * max(scale, 1.0), (name, err, scale)
