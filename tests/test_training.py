"""Training substrate: loss goes down; optimizer specs are valid; resume
from checkpoint continues bit-exactly."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.training import optimizer as opt
from repro.training.data import SyntheticTokens
from repro.training.train_step import make_train_step

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32")


def test_loss_decreases():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params)
    step = jax.jit(make_train_step(
        model, opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        remat="none", grad_dtype=None))
    data = iter(SyntheticTokens(CFG, 4, 32, seed=0))
    first = None
    for i in range(40):
        params, state, metrics = step(params, state, next(data))
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < 0.7 * first


def test_bf16_grad_compression_still_learns():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params)
    step = jax.jit(make_train_step(
        model, opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        remat="none", grad_dtype="bfloat16"))
    data = iter(SyntheticTokens(CFG, 4, 32, seed=0))
    first = None
    for i in range(30):
        params, state, metrics = step(params, state, next(data))
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < 0.8 * first


def test_remat_matches_no_remat():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(1))
    batch = next(iter(SyntheticTokens(CFG, 2, 16, seed=1)))
    g1 = jax.grad(lambda p: model.loss(p, batch, remat="none")[0])(params)
    g2 = jax.grad(lambda p: model.loss(p, batch, remat="full")[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_checkpoint_resume_bitexact(tmp_path):
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params)
    step = jax.jit(make_train_step(model, opt.AdamWConfig(lr=1e-3),
                                   remat="none", grad_dtype=None))
    data = list(SyntheticTokens(CFG, 2, 16, seed=2).__next__()
                for _ in range(6))
    # straight run
    p1, s1 = params, state
    for b in data:
        p1, s1, _ = step(p1, s1, b)
    # run with save/restore in the middle
    mgr = CheckpointManager(str(tmp_path))
    p2, s2 = params, state
    for b in data[:3]:
        p2, s2, _ = step(p2, s2, b)
    mgr.save(3, (p2, s2))
    (p2, s2), _ = mgr.restore((p2, s2))
    for b in data[3:]:
        p2, s2, _ = step(p2, s2, b)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert float(jnp.max(jnp.abs(a - b))) == 0.0


def test_zero1_state_specs_divisible():
    """Every ZeRO-1 sharded dim must divide 32 (pod x data)."""
    from repro.configs import get_config
    from jax.sharding import PartitionSpec as P
    from repro.models.common import map_defs
    for arch in ("granite-3-8b", "grok-1-314b", "jamba-v0.1-52b"):
        model = build_model(get_config(arch))
        specs = opt.state_specs(model.defs, zero1=True)

        def check(d, s):
            parts = list(s) + [None] * (len(d.shape) - len(s))
            for dim, part in zip(d.shape, parts):
                names = () if part is None else (
                    (part,) if isinstance(part, str) else part)
                if "data" in names or "pod" in names:
                    assert dim % 32 == 0, (arch, d.shape, s)

        jax.tree.map(check, model.defs, specs["mu"],
                     is_leaf=lambda x: hasattr(x, "axes"))
