"""Pallas kernels validated in interpret mode against the jnp oracles,
sweeping shapes and dtypes."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.wkv6 import wkv6

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 3e-2


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,sk,hq,hkv,hd,causal", [
    (2, 128, 128, 4, 2, 64, True),
    (1, 200, 200, 8, 8, 128, True),
    (2, 64, 256, 6, 2, 32, False),
    (1, 257, 257, 4, 1, 64, True),
    (2, 96, 96, 2, 2, 16, True),
])
def test_flash_attention(b, sq, sk, hq, hkv, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert float(err) < _tol(dtype), err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,hd,kvb", [
    (2, 256, 8, 2, 64, 64),
    (3, 100, 4, 4, 32, 32),
    (1, 1024, 16, 8, 128, 256),
    (2, 77, 6, 1, 64, 16),
])
def test_decode_attention(b, s, hq, hkv, hd, kvb, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, 1, hq, hd), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, kc, vc, lens, kv_block=kvb, interpret=True)
    want = ref.decode_attention_reference(q, kc, vc, lens)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - want.astype(jnp.float32)))
    assert float(err) < _tol(dtype), err


@pytest.mark.parametrize("b,t,h,n,chunk", [
    (2, 64, 2, 32, 16),
    (1, 100, 4, 64, 32),
    (2, 33, 1, 16, 8),
    (1, 16, 2, 64, 64),     # t < chunk
])
def test_wkv6(b, t, h, n, chunk):
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) * 0.5
               for i in range(3))
    w = jax.random.normal(ks[3], (b, t, h, n)) * 0.5
    u = jax.random.normal(ks[4], (h, n)) * 0.5
    s0 = jax.random.normal(ks[5], (b, h, n, n)) * 0.1
    y, sT = wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.wkv6_reference(r, k, v, w, u, s0)
    assert float(jnp.max(jnp.abs(y - yr))) < 2e-4
    assert float(jnp.max(jnp.abs(sT - sr))) < 2e-4


def test_wkv6_chunked_ref_matches_plain():
    ks = jax.random.split(KEY, 5)
    b, t, h, n = 2, 70, 2, 32
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) * 0.5
               for i in range(3))
    w = jax.random.normal(ks[3], (b, t, h, n)) * 0.5
    u = jax.random.normal(ks[4], (h, n)) * 0.5
    y1, s1 = ref.wkv6_reference(r, k, v, w, u)
    y2, s2 = ref.wkv6_chunked(r, k, v, w, u, chunk=16)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-5
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-5


def test_flash_blocked_matches_naive_long():
    ks = jax.random.split(KEY, 3)
    b, s, hq, hkv, hd = 1, 500, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    out = ref.flash_attention_blocked(q, k, v, causal=True, q_block=128,
                                      kv_block=128)
    want = ref.mha_reference(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-5


def test_flash_blocked_grad_matches_naive():
    """The checkpointed blocked attention must be differentiable and agree
    with the naive gradient."""
    ks = jax.random.split(KEY, 3)
    b, s, hq, hkv, hd = 1, 96, 2, 1, 16
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))

    def f_blocked(q):
        return jnp.sum(ref.flash_attention_blocked(
            q, k, v, causal=True, q_block=32, kv_block=32) ** 2)

    def f_naive(q):
        return jnp.sum(ref.mha_reference(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_blocked)(q)
    g2 = jax.grad(f_naive)(q)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 5e-4
