"""Request-lifecycle serving API: seeded sampling determinism, finish
reasons, streaming event order, per-request step metrics, prefill-time
finishing, endpoint lifecycle (in-place consolidation, source-engine
retirement), and the serverless frontend glue."""

import jax
import pytest

from conftest import smoke
from repro.core import GB, Gbps, ModelProfile, ServerSpec, SLO, TimingProfile
from repro.models import build_model
from repro.serving.api import FinishReason, SamplingParams
from repro.serving.endpoint import ServerlessFrontend, ServingEndpoint
from repro.serving.engine import Engine

PROMPT = [5, 7, 9, 11]
SAMPLED = SamplingParams(max_new=10, temperature=0.8, top_k=8, seed=7)


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _run_one(cfg, params, sp, prompt=PROMPT, **eng_kw):
    eng_kw.setdefault("max_batch", 2)
    eng_kw.setdefault("max_seq", 64)
    ep = ServingEndpoint(Engine(cfg, [params], **eng_kw))
    r = ep.submit(prompt, sp)
    ep.run()
    return r


def _greedy_tokens(cfg, params, max_new=10):
    return _run_one(cfg, params, SamplingParams(max_new=max_new)).generated


# ------------------------------------------------------------- sampling
def test_seeded_sampling_deterministic_across_layouts(granite):
    """Same (seed, prompt) -> same stream, regardless of KV layout; the
    PRNG key depends only on (seed, token index)."""
    cfg, params = granite
    streams = {}
    for paged in (False, True):
        streams[paged] = _run_one(cfg, params, SAMPLED, paged=paged).generated
    assert streams[False] == streams[True]
    assert len(streams[False]) == SAMPLED.max_new
    # re-running the same engine config reproduces the stream exactly
    assert _run_one(cfg, params, SAMPLED).generated == streams[False]
    # a different seed diverges (512-token vocab, 10 draws)
    other = _run_one(cfg, params,
                     SamplingParams(max_new=10, temperature=0.8, top_k=8,
                                    seed=8)).generated
    assert other != streams[False]
    # greedy is unaffected by seed: temperature 0 ignores the PRNG
    g1 = _run_one(cfg, params, SamplingParams(max_new=10, seed=1)).generated
    g2 = _run_one(cfg, params, SamplingParams(max_new=10, seed=2)).generated
    assert g1 == g2 == _greedy_tokens(cfg, params)


def test_sampled_stream_survives_consolidation(granite):
    """Sampling keys don't depend on engine identity — a §6.2 scale-down
    mid-stream continues the sampled stream bit-exactly."""
    cfg, params = granite
    want = _run_one(cfg, params, SAMPLED).generated
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(Engine(cfg, sp, max_batch=2, max_seq=64))
    r = ep.submit(PROMPT, SAMPLED)
    for _ in range(3):
        ep.step()
    ep.consolidate(params)
    ep.run()
    assert r.generated == want


# -------------------------------------------------------- finish reasons
def test_eos_and_stop_token_finish_reasons(granite):
    cfg, params = granite
    greedy = _greedy_tokens(cfg, params)
    eos = _run_one(cfg, params,
                   SamplingParams(max_new=10, eos_token=greedy[2]))
    assert eos.generated == greedy[:3]           # eos token is included
    assert eos.finish_reason is FinishReason.EOS
    stop = _run_one(cfg, params,
                    SamplingParams(max_new=10, stop_tokens=(greedy[4],)))
    assert stop.generated == greedy[:5]
    assert stop.finish_reason is FinishReason.STOP_TOKEN
    length = _run_one(cfg, params, SamplingParams(max_new=10))
    assert length.finish_reason is FinishReason.LENGTH
    out = length.output()
    assert out.done and out.token_ids == tuple(greedy)
    assert out.finish_reason is FinishReason.LENGTH


def test_finish_at_prefill_frees_slot_immediately(granite):
    """Regression (satellite): max_new=1 (or eos on the prefill token)
    finishes during admission — no wasted decode step, and the freed slot
    is reusable within the same scheduler step."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=1, max_seq=64)
    a = eng.submit([1, 2, 3], SamplingParams(max_new=1))
    b = eng.submit([4, 5, 6], SamplingParams(max_new=1))
    out = eng.step()
    # both admitted, prefilled, finished in ONE step through one slot
    assert eng.steps == 1 and a.done and b.done
    assert a.metrics.decode_steps == b.metrics.decode_steps == 0
    assert a.finish_reason is FinishReason.LENGTH
    assert [ev.rid for ev in out.events] == [a.rid, b.rid]
    assert out.finished == (a.rid, b.rid)
    assert eng.block_mgr.free_blocks == eng.block_mgr.n_blocks
    # eos on the prefill token finishes at prefill too
    first = _greedy_tokens(cfg, params)[0]
    c = eng.submit(PROMPT, SamplingParams(max_new=5, eos_token=first))
    eng.step()
    assert c.done and c.finish_reason is FinishReason.EOS
    assert c.metrics.decode_steps == 0


# ------------------------------------------------------------- streaming
def test_streaming_event_order_and_coverage(granite):
    """Per step: prefill events (admission order) then decode events
    (slot order); concatenated per-rid events equal the final streams."""
    cfg, params = granite
    ep = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64))
    r0 = ep.submit(PROMPT, SamplingParams(max_new=4))
    r1 = ep.submit([3, 1, 4, 1, 5], SamplingParams(max_new=6))
    first = ep.step()
    # step 1: both prefills, then both decodes, in rid==slot order
    assert [ev.rid for ev in first.events] == [r0.rid, r1.rid,
                                               r0.rid, r1.rid]
    outs = [first] + ep.run()
    streams = {r0.rid: [], r1.rid: []}
    for out in outs:
        assert out.step >= 1
        for ev in out.events:
            streams[ev.rid].append(ev.token)
            if ev.finish_reason is not None:
                assert ev.rid in out.finished
    assert streams[r0.rid] == r0.generated
    assert streams[r1.rid] == r1.generated


def test_generate_yields_matching_stream(granite):
    cfg, params = granite
    want = _greedy_tokens(cfg, params, max_new=6)
    ep = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64))
    events = list(ep.generate(PROMPT, SamplingParams(max_new=6)))
    assert [ev.token for ev in events] == want
    assert events[-1].finish_reason is FinishReason.LENGTH
    assert all(ev.finish_reason is None for ev in events[:-1])


# --------------------------------------------------------------- metrics
def test_metrics_immediate_admission(granite):
    cfg, params = granite
    r = _run_one(cfg, params, SamplingParams(max_new=8))
    m = r.metrics
    assert m.ttft_steps == 1 and m.queue_steps == 0
    assert m.decode_steps == 7            # prefill token + 7 decode tokens
    assert m.n_tokens == 8
    assert m.tpot_steps == 1.0            # decoded every resident step
    # step 1 emits two tokens (prefill + same-step decode), steps 2..7 one
    assert m.finish_step == m.admit_step + 6


def test_metrics_deferred_admission_counts_queue_steps(granite):
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64, paged=True)
    bs = eng.block_mgr.block_size
    eng.block_mgr.allocate(-1, eng.block_mgr.n_blocks * bs)  # pool hogged
    r = eng.submit(PROMPT, SamplingParams(max_new=4))
    for _ in range(3):
        eng.step()                        # admission starved
    assert r.metrics.admit_step is None and r.metrics.ttft_steps is None
    eng.block_mgr.free(-1)
    eng.run()
    assert r.done
    assert r.metrics.queue_steps == 3
    assert r.metrics.ttft_steps == 4


def test_step_output_counts_prefill_tokens(granite):
    """StepOutput.prefill_tokens reports the prompt rows computed this
    step: the whole prompt for monolithic engines, chunk-bounded (and
    shrunk by prefix-cache hits) otherwise."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64)
    eng.submit(PROMPT, SamplingParams(max_new=2))
    eng.submit([3, 1, 4], SamplingParams(max_new=2))
    first = eng.step()
    assert first.prefill_tokens == len(PROMPT) + 3
    assert eng.step().prefill_tokens == 0

    chunked = Engine(cfg, [params], max_batch=2, max_seq=64, paged=True,
                     block_size=4, prefix_cache=True, prefill_chunk=4)
    r = chunked.submit(list(range(10)), SamplingParams(max_new=2))
    outs = chunked.run()
    assert [o.prefill_tokens for o in outs[:3]] == [4, 4, 2]
    assert r.metrics.cached_tokens == 0          # cold cache
    # identical prompt: the two full prefix blocks are reused, only the
    # partial-block suffix is recomputed
    r2 = chunked.submit(list(range(10)), SamplingParams(max_new=2))
    outs = chunked.run()
    assert outs[0].prefill_tokens == 2
    assert r2.metrics.cached_tokens == 8
    assert r2.generated == r.generated


# ------------------------------------------------------------- lifecycle
def test_retired_source_engine_raises(granite):
    """Satellite: after the endpoint swaps engines, the old engine must
    raise instead of silently driving block tables it no longer owns."""
    cfg, params = granite
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(Engine(cfg, sp, max_batch=2, max_seq=64))
    r = ep.submit(PROMPT, SamplingParams(max_new=6))
    ep.step()
    stale = ep.engine
    ep.consolidate(params)
    assert ep.engine is not stale
    for call in (lambda: stale.submit(PROMPT, SamplingParams(max_new=2)),
                 stale.step, stale.run,
                 lambda: stale.consolidated(params),
                 lambda: stale.scale_up(params)):
        with pytest.raises(RuntimeError, match="retired"):
            call()
    assert stale.active() == [] and not stale.workers
    ep.run()                              # the live handle still serves
    assert r.done


def test_frontend_cold_start_to_endpoint(granite):
    """ServerlessFrontend: Alg.1 plan -> stage slicing -> live endpoint;
    output matches the single-worker reference across consolidation."""
    cfg, params = granite
    servers = {f"srv{i}": ServerSpec(f"srv{i}", 16 * Gbps, 12e9, 24 * GB)
               for i in range(4)}
    front = ServerlessFrontend(servers)
    front.deploy(cfg, params, ModelProfile(
        cfg.name, int(12.5 * GB), TimingProfile(), SLO(ttft=7.5, tpot=0.2)))
    ep = front.cold_start(cfg.name, min_stages=2, max_batch=2, max_seq=64)
    assert ep.scheme is not None and ep.n_stages >= 2
    r = ep.submit(PROMPT, SamplingParams(max_new=8))
    for _ in range(2):
        ep.step()
    ep.consolidate(front.full_params(cfg.name))
    assert ep.n_stages == 1
    ep.run()
    assert r.generated == _greedy_tokens(cfg, params, max_new=8)


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy
