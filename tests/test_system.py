"""End-to-end behaviour of the paper's system: the headline claims hold
qualitatively in this reproduction (cold-start TTFT reduction, SLO
attainment, consolidation wins)."""

import jax
import pytest

from conftest import smoke
from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import (APPLICATIONS, WARM, kv_bytes_for,
                                          timings_for)
from repro.workloads.generator import burst, generate, make_instances


def servers():
    return ([ServerSpec(f"a10-{i}", 16 * Gbps, 12e9, 24 * GB, 1)
             for i in range(4)]
            + [ServerSpec(f"v100-{i}", 16 * Gbps, 12e9, 32 * GB, 4)
               for i in range(4)])


def profiles():
    return {n: ModelProfile(n, w.size_bytes, timings_for(n), SLO(7.5, 0.2),
                            kv_bytes_per_token=kv_bytes_for(n))
            for n, w in WARM.items()}


def _cold_ttft(system, model="llama2-13b", **kw):
    apps = [a for a in APPLICATIONS if a.model == model]
    insts = make_instances(apps[:1], 1, slo_scale=100.0)
    sim = ServerlessSim(servers(), profiles(), insts, system=system, **kw)
    reqs = burst(insts[0], 1)
    sim.submit(reqs)
    sim.run(until=600)
    return reqs[0].ttft


def test_pipeline_parallel_cold_start_beats_baselines():
    """Paper Fig. 8: hydra < serverlessllm < serverless vLLM."""
    vllm = _cold_ttft("vllm")
    sllm = _cold_ttft("serverlessllm")
    hydra = _cold_ttft("hydra", force_s=4)
    assert hydra < sllm < vllm
    assert vllm / hydra > 1.5          # meaningful reduction


def test_slo_attainment_improves():
    """Paper Fig. 10: hydra's TTFT attainment beats serverless vLLM."""
    res = {}
    for system in ("vllm", "hydra"):
        insts = make_instances(APPLICATIONS, 32)
        sim = ServerlessSim(servers(), profiles(), insts, system=system)
        reqs = generate(insts, rps=0.6, cv=8.0, duration=400, seed=0)
        sim.submit(reqs)
        sim.run(until=4000)
        res[system] = sim.metrics()
    assert res["hydra"]["ttft_attainment"] > res["vllm"]["ttft_attainment"]
    assert res["hydra"]["tpot_attainment"] > 0.85


def test_scale_down_reduces_e2e_generation():
    """Paper Fig. 13: consolidation shortens end-to-end generation."""
    from repro.workloads.generator import ModelInstance, Request

    def one(consolidate):
        inst = ModelInstance("m#0", "chatbot-13b", "llama2-13b",
                             1e9, 1e9, 512, 512)
        sim = ServerlessSim(servers(), profiles(), [inst], system="hydra",
                            force_s=4, consolidate=consolidate)
        req = Request(0, inst.name, inst.app, 0.0, 512, 512, 1e9, 1e9)
        sim.submit([req])
        sim.run(until=1200)
        return req.completion

    assert one(True) < one(False)


def test_engine_cold_to_warm_path(rng):
    """Functional twin: a pipeline group serves, consolidates, keeps
    serving — outputs identical to a never-cold worker."""
    cfg = smoke("granite-3-8b")
    m = build_model(cfg)
    params = m.init(rng)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(Engine(cfg, sp, max_batch=2, max_seq=64))
    r = ep.submit([9, 8, 7], SamplingParams(max_new=8))
    for _ in range(4):
        ep.step()
    ep.consolidate(params)
    r2 = ep.submit([9, 8, 7], SamplingParams(max_new=8))  # warm request
    ep.run()
    ref = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64))
    rr = ref.submit([9, 8, 7], SamplingParams(max_new=8))
    ref.run()
    assert r.generated == rr.generated == r2.generated
