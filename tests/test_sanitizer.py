"""KV-lifecycle sanitizer: fuzz coverage and seeded-bug detection.

Two halves of the tentpole contract:

  * randomized sessions under ``Engine(sanitize=True)`` — fresh prompts,
    multi-turn continuations, verbatim revisits through a tight KV tier
    (spill → restore), forced preemption, §6.2 consolidation, and the
    int8/fp16 ``kv_dtype`` variants — produce ZERO sanitizer findings,
    pass the quiescence audit, and stream bit-exactly with the same
    session run sanitize-off (the off path carries no instrumentation:
    every tracer endpoint stays ``None``);
  * seeded bugs are DETECTED — the PR 7 evict-before-notify class
    (an eviction that reuses the block id without firing its hook), an
    injected double-free, and a read of a freshly-allocated,
    never-written page each surface as the matching finding kind.
"""

import random

import jax
import pytest

from conftest import smoke
from repro.kernels import ops
from repro.models.model import build_model
from repro.router import KVBlockStore
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine
from repro.serving.kvcache import KVInvariantError

PROMPTS = [
    [1, 2, 3, 4, 5, 6, 7],
    [9, 8, 7, 6, 5],
    [3, 1, 4, 1, 5, 9, 2, 6, 5, 3],
    [11, 12, 13],
]


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    return cfg, build_model(cfg).init(jax.random.PRNGKey(0))


def _engine(cfg, params, *, sanitize, tier=None, **kw):
    return Engine(cfg, [params], max_batch=2, max_seq=32, block_size=8,
                  paged=True, prefix_cache=True, kv_tier=tier,
                  sanitize=sanitize, **kw)


def _fuzz_session(cfg, params, *, sanitize, kv_dtype, seed):
    """One randomized multi-turn session. The RNG only drives prompt
    construction, so the same seed replays the identical workload with
    sanitize on or off; the 10-block pool under a tight host tier forces
    evictions (spills) and revisits of evicted prefixes (restores)."""
    tier = KVBlockStore(host_capacity_blocks=32)
    eng = _engine(cfg, params, sanitize=sanitize, tier=tier,
                  kv_dtype=kv_dtype)
    rng = random.Random(seed)
    convs = []
    streams = []
    for _ in range(16):
        roll = rng.random()
        if convs and roll < 0.30:
            # multi-turn continuation: prior prompt + its reply + a new
            # token — a prefix hit whose blocks may need a tier restore
            base, reply = rng.choice(convs)
            prompt = (base + reply + [rng.randrange(1, 400)])[:20]
        elif convs and roll < 0.45:
            prompt = list(rng.choice(convs)[0])       # verbatim revisit
        else:
            # 12-16 tokens: each fresh prompt commits 2+ full blocks so
            # the 10-block pool churns and the tier sees real spills
            prompt = [rng.randrange(1, 400)
                      for _ in range(rng.randrange(12, 17))]
        toks = [ev.token for ev in
                eng.generate(prompt, SamplingParams(
                    max_new=rng.randrange(2, 6)))]
        convs.append((prompt, toks))
        streams.append(toks)
    # revisit the oldest conversations verbatim: their blocks were pushed
    # out of the 10-block pool long ago, so these are tier restores
    for base, _ in convs[:3]:
        streams.append([ev.token for ev in
                        eng.generate(base, SamplingParams(max_new=4))])
    # forced preemption mid-decode, then drain
    a = eng.submit([7] * 12, SamplingParams(max_new=6))
    b = eng.submit([9] * 12, SamplingParams(max_new=6))
    for _ in range(3):
        eng.step()
    eng.preempt(a)
    eng.run()
    streams += [list(a.generated), list(b.generated)]
    return streams, eng, tier


@pytest.mark.parametrize("kv_dtype", ["float32", "float16", "int8"])
def test_fuzz_clean_and_bit_exact(granite, kv_dtype):
    """The randomized session audits clean end to end, actually covers
    the spill/restore and preemption paths, passes the quiescence
    refcount audit against the real BlockManager, and its streams are
    bit-identical to the sanitize-off run of the same seed."""
    cfg, params = granite
    on, eng, tier = _fuzz_session(cfg, params, sanitize=True,
                                  kv_dtype=kv_dtype, seed=1234)
    assert eng.sanitizer is not None
    assert eng.block_mgr.evictions > 0 and tier.spills > 0, \
        "fuzz session must exercise eviction -> spill"
    assert tier.restores > 0, "fuzz session must exercise restore"
    assert eng.sanitizer.events > 0
    eng.sanitizer.check_idle()
    eng.sanitizer.raise_if_findings()

    off, eng_off, _ = _fuzz_session(cfg, params, sanitize=False,
                                    kv_dtype=kv_dtype, seed=1234)
    assert off == on
    assert eng_off.sanitizer is None


def test_sanitize_off_leaves_no_instrumentation(granite):
    """sanitize=False is the exact pre-instrumentation engine: every
    tracer endpoint stays None and no hooks were appended."""
    cfg, params = granite
    tier = KVBlockStore(host_capacity_blocks=4)
    eng = _engine(cfg, params, sanitize=False, tier=tier)
    assert eng.sanitizer is None
    assert eng.block_mgr.tracer is None
    assert eng.runner.tracer is None
    assert all(w.tracer is None for w in eng.runner.workers)
    assert tier.tracer is None


def test_env_mode_enables_and_paged_required(granite):
    """REPRO_SANITIZE (via ops.set_sanitize_mode) turns the sanitizer on
    by default for paged engines; asking for it on a non-paged engine is
    a hard configuration error."""
    cfg, params = granite
    ops.set_sanitize_mode(True)
    try:
        eng = Engine(cfg, [params], max_batch=2, max_seq=32, block_size=8,
                     paged=True)
        assert eng.sanitizer is not None
        legacy = Engine(cfg, [params], max_batch=2, max_seq=32,
                        paged=False)
        assert legacy.sanitizer is None    # nothing to shadow
    finally:
        ops.set_sanitize_mode(False)
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, [params], paged=False, sanitize=True)


def test_consolidation_carries_sanitizer_clean(granite):
    """§6.2 scale-down mid-flight with a preempted request: the
    successor adopts the same sanitizer (rebound to its runner/workers),
    the migration gather is byte-checked against the BlockManager quote,
    and the full session still audits clean and matches the
    uninterrupted 1-stage streams."""
    cfg, params = granite
    ref = _engine(cfg, params, sanitize=False)
    want = [ref.submit(p, SamplingParams(max_new=6)) for p in PROMPTS[:2]]
    ref.run()

    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    eng = Engine(cfg, sp, max_batch=2, max_seq=32, block_size=8,
                 paged=True, prefix_cache=True, sanitize=True,
                 prefill_chunk=4)
    a = eng.submit(PROMPTS[0], SamplingParams(max_new=6))
    b = eng.submit(PROMPTS[1], SamplingParams(max_new=6))
    for _ in range(3):
        eng.step()
    eng.preempt(a)
    san = eng.sanitizer
    eng2 = eng.consolidated(params)
    assert eng2.sanitizer is san          # adopted, not re-created
    assert eng2.block_mgr.tracer is san
    assert all(w.tracer is san for w in eng2.runner.workers)
    eng2.run()
    assert [list(a.generated), list(b.generated)] == \
        [list(r.generated) for r in want]
    san.check_idle()
    san.raise_if_findings()


# ---------------------------------------------------------------------------
# Seeded bugs: each class the sanitizer exists for must be DETECTED
# ---------------------------------------------------------------------------


def _churn(eng, n=8):
    for i in range(n):
        eng.submit([10 * i + j + 1 for j in range(16)],
                   SamplingParams(max_new=8))
        eng.run()


def test_seeded_evict_before_notify_detected(granite):
    """Re-introduce the PR 7 bug class: an eviction that drops the index
    entry and reuses the block id WITHOUT firing the evict hook. The
    sanitizer's shadow index still maps the block when it is handed out
    again and flags evict-before-notify."""
    cfg, params = granite
    eng = _engine(cfg, params, sanitize=True)
    bm = eng.block_mgr

    def silent_take():                     # the buggy _take_block
        if bm._free:
            return bm._free.pop()
        blk, _ = bm._cached.popitem(last=False)
        h = bm._hash_of.pop(blk)
        if bm._index.get(h) == blk:
            del bm._index[h]               # ...but never notifies
        bm.evictions += 1
        return blk

    bm._take_block = silent_take
    _churn(eng)                            # 8x3 blocks > 10-block pool
    assert bm.evictions > 0
    kinds = {f.kind for f in eng.sanitizer.findings}
    assert "evict-before-notify" in kinds, eng.sanitizer.report()


def test_seeded_double_free_detected(granite):
    """free() of a request whose table was already dropped at finish."""
    cfg, params = granite
    eng = _engine(cfg, params, sanitize=True)
    r = eng.submit(PROMPTS[0], SamplingParams(max_new=3))
    eng.run()
    eng.block_mgr.free(r.rid)              # second free: table is gone
    kinds = {f.kind for f in eng.sanitizer.findings}
    assert "double-free" in kinds, eng.sanitizer.report()


def test_seeded_uncommitted_read_detected(granite):
    """A page read of a freshly-allocated block whose rows were never
    prefilled, decoded, restored, or copied."""
    cfg, params = granite
    eng = _engine(cfg, params, sanitize=True)
    t = eng.block_mgr.allocate(999, 8, tokens=list(range(100, 108)))
    eng.runner.read_pages(t.blocks[0])     # nothing ever wrote this page
    kinds = {f.kind for f in eng.sanitizer.findings}
    assert "uncommitted-read" in kinds, eng.sanitizer.report()


def test_strict_mode_raises_at_first_finding(granite):
    cfg, params = granite
    eng = _engine(cfg, params, sanitize=True)
    eng.sanitizer.strict = True
    with pytest.raises(KVInvariantError, match="free-unknown"):
        eng.block_mgr.free(31337)          # rid that never existed
