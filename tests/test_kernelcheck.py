"""Pallas launch checker: contract units + ops-dispatch integration.

Well-formed ragged/decode launches pass silently; every contract
violation (rank, tile alignment, scalar-prefetch shapes/dtypes, the
signed pad-row convention, quant-leaf shapes, concrete page-id / row /
pos / kv_len ranges) raises :class:`KernelContractError` with an
actionable message. Tile-alignment problems are hard errors only under
the compiled ``pallas`` backend — the CPU ``ref``/``interpret`` paths
warn, since smoke shapes are legitimately tiny. With sanitize mode on,
the checks run from the ``kernels/ops.py`` dispatch itself.
"""

import numpy as np
import pytest

from repro.analysis.kernelcheck import (KernelContractError,
                                        check_paged_decode,
                                        check_ragged_paged)
from repro.kernels import ops

HD = 128          # lane-aligned head_dim: no alignment warnings
BS = 8            # sublane-aligned page_size


def _pool(n_pages=6, hkv=2, dtype=np.float32):
    k = np.zeros((n_pages, BS, hkv, HD), dtype)
    return k, k.copy()


def _ragged_args(t=16, hq=4, b=2, nb=4):
    q = np.zeros((t, hq, HD), np.float32)
    k, v = _pool()
    tables = np.zeros((b, nb), np.int32)
    row = np.repeat(np.arange(t // 8) % b, 8).astype(np.int32)
    pos = np.where(np.arange(t) % 8 < 5, np.arange(t) % 8, -1)
    return q, k, v, tables, row, pos.astype(np.int32)


def _decode_args(b=2, hq=4, nb=4):
    q = np.zeros((b, 1, hq, HD), np.float32)
    k, v = _pool()
    tables = np.zeros((b, nb), np.int32)
    kv_len = np.array([9, 17][:b], np.int32)
    return q, k, v, tables, kv_len


def test_good_launches_pass():
    check_ragged_paged(*_ragged_args())
    check_paged_decode(*_decode_args())


@pytest.mark.parametrize("mutate, match", [
    (lambda a: (a[0][0], *a[1:]), "q must be"),                 # q rank 2
    (lambda a: (a[0][:12], *a[1:]), "tile_q"),                  # T % 8 != 0
    (lambda a: (a[0][:, :3], *a[1:]), "GQA"),                   # Hq % Hkv
    (lambda a: (a[0][:, :, :64], *a[1:]),
     "head_dim"),                                               # q hd mismatch
    (lambda a: (*a[:3], a[3][0], *a[4:]), "tables must be"),
    (lambda a: (*a[:4], a[4][:8], a[5]), "row must be"),
    (lambda a: (*a[:4], a[4].astype(np.float32), a[5]), "integer"),
    (lambda a: (*a[:5], a[5].astype(np.uint32)), "signed"),     # pad -1
])
def test_ragged_shape_violations(mutate, match):
    with pytest.raises(KernelContractError, match=match):
        check_ragged_paged(*mutate(_ragged_args()))


def test_ragged_concrete_value_violations():
    q, k, v, tables, row, pos = _ragged_args()
    bad_tables = tables.copy()
    bad_tables[0, 0] = 99                           # page id out of pool
    with pytest.raises(KernelContractError, match="page ids outside"):
        check_ragged_paged(q, k, v, bad_tables, row, pos)
    bad_row = row.copy()
    bad_row[3] = 1 - bad_row[3]                     # row flips inside a tile
    with pytest.raises(KernelContractError, match="inside query tile"):
        check_ragged_paged(q, k, v, tables, bad_row, pos)
    bad_pos = pos.copy()
    bad_pos[0] = -2                                 # below the pad marker
    with pytest.raises(KernelContractError, match="pad marker"):
        check_ragged_paged(q, k, v, tables, row, bad_pos)


def test_quant_leaf_contract():
    q, k, v, tables, row, pos = _ragged_args()
    k8, v8 = k.astype(np.int8), v.astype(np.int8)
    good = {l: np.zeros(k.shape[:-1], np.float32)
            for l in ("k_scale", "k_zero", "v_scale", "v_zero")}
    check_ragged_paged(q, k8, v8, tables, row, pos, kv_quant=good)
    with pytest.raises(KernelContractError, match="missing leaves"):
        check_ragged_paged(q, k8, v8, tables, row, pos,
                           kv_quant={"k_scale": good["k_scale"]})
    bad = dict(good, k_zero=good["k_zero"][:, :4])
    with pytest.raises(KernelContractError, match="shape"):
        check_ragged_paged(q, k8, v8, tables, row, pos, kv_quant=bad)
    bad = dict(good, v_scale=good["v_scale"].astype(np.float16))
    with pytest.raises(KernelContractError, match="float32"):
        check_ragged_paged(q, k8, v8, tables, row, pos, kv_quant=bad)


@pytest.mark.parametrize("mutate, match", [
    (lambda a: (a[0][:, 0], *a[1:]), "q must be"),
    (lambda a: (a[0], a[1][0], *a[2:]), "k_pages must be"),
    (lambda a: (a[0], a[1], a[2].astype(np.float16), *a[3:]), "dtype"),
    (lambda a: (*a[:3], a[3][:1], a[4]), "block_tables must be"),
    (lambda a: (*a[:4], a[4][:1]), "kv_len must be"),
])
def test_decode_shape_violations(mutate, match):
    with pytest.raises(KernelContractError, match=match):
        check_paged_decode(*mutate(_decode_args()))


def test_decode_concrete_value_violations():
    q, k, v, tables, kv_len = _decode_args()
    bad = tables.copy()
    bad[1, 2] = -1
    with pytest.raises(KernelContractError, match="page ids outside"):
        check_paged_decode(q, k, v, bad, kv_len)
    with pytest.raises(KernelContractError, match="exceeds the"):
        check_paged_decode(q, k, v, tables,
                           np.array([9, 999], np.int32))


def test_alignment_severity_by_backend():
    """head_dim % 128 / page_size % 8: error on the compiled pallas
    backend, warning on ref/interpret where CPU smoke shapes are fine."""
    q = np.zeros((2, 1, 4, 64), np.float32)
    k = np.zeros((6, 8, 2, 64), np.float32)
    tables = np.zeros((2, 4), np.int32)
    kv_len = np.array([3, 5], np.int32)
    with pytest.warns(UserWarning, match="not a multiple of 128"):
        check_paged_decode(q, k, k.copy(), tables, kv_len, backend="ref")
    with pytest.raises(KernelContractError, match="not a multiple of 128"):
        check_paged_decode(q, k, k.copy(), tables, kv_len,
                          backend="pallas")


def test_null_page_required():
    q, k, v, tables, kv_len = _decode_args()
    solo = k[:1]
    with pytest.raises(KernelContractError, match="null/trash"):
        check_paged_decode(q, solo, solo.copy(),
                           np.zeros((2, 4), np.int32), kv_len)


def test_ops_dispatch_runs_checks_in_sanitize_mode():
    """kernels/ops.py calls the checker before dispatch when sanitize
    mode is on — a malformed launch dies with the contract error instead
    of a kernel-side shape blowup (and is not checked when off)."""
    q, k, v, tables, kv_len = _decode_args()
    bad_len = np.array([9, 999], np.int32)
    ops.set_sanitize_mode(True)
    try:
        with pytest.raises(KernelContractError, match="exceeds the"):
            ops.paged_decode_attention(q, k, v, tables, bad_len)
        qr, kr, vr, tr, row, pos = _ragged_args()
        with pytest.raises(KernelContractError, match="signed"):
            ops.ragged_paged_attention(qr, kr, vr, tr, row,
                                       pos.astype(np.uint32))
    finally:
        ops.set_sanitize_mode(False)
    # off: a well-formed launch reaches the kernel untouched
    import jax.numpy as jnp
    out = ops.paged_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                     jnp.asarray(v), jnp.asarray(tables),
                                     jnp.asarray(kv_len))
    assert out.shape == q.shape
