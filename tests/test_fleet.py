"""Fleet control plane: shared policy units, Alg. 1 proactive
distribution, and the real-JAX multi-model frontend (scale-to-zero,
queued cold starts, cold-deploy, placement-accelerated launches)."""

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.controller import CentralController
from repro.core.types import (GB, Gbps, ModelProfile, ServerSpec, SLO,
                              TimingProfile)
from repro.fleet import FleetFrontend
from repro.fleet.controller import FleetController, FleetPolicy
from repro.models import build_model
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import (APPLICATIONS, WARM, kv_bytes_for,
                                          timings_for)
from repro.workloads.generator import make_instances, periodic_bursts

T = TimingProfile(t_cc=0.2, t_l=0.2, t_cu=0.1)


def _servers(n=2, nic=16 * Gbps, hbm=24 * GB):
    return {f"s{i}": ServerSpec(f"s{i}", nic, 12e9, hbm, 1)
            for i in range(n)}


def _central(n=2, **kw):
    return CentralController(_servers(n), **kw)


def _profile(name="m", size=4 * GB, max_pp=4):
    return ModelProfile(name, size, T, SLO(10.0, 0.5), max_pp=max_pp,
                        kv_bytes_per_token=1024)


def _burst(fc, model, at, n=3, gap=0.5):
    for k in range(n):
        fc.record_arrival(model, at + k * gap)


# ====================================================== policy decisions
def test_episode_period_learning():
    fc = FleetController(_central(), FleetPolicy.proactive())
    for t0 in (0.0, 100.0, 200.0):
        _burst(fc, "m", t0)
    # two full inter-episode spans of 100 s -> next burst predicted at 300
    assert fc.predicted_next_episode("m", 210.0) == pytest.approx(300.0)
    # missed predictions roll whole periods forward, never trailing `now`
    assert fc.predicted_next_episode("m", 310.0) == pytest.approx(400.0)
    assert fc.predicted_next_episode("none", 10.0) is None


def test_keepalive_delayed_downscale():
    naive = FleetController(_central(), FleetPolicy.naive(keepalive_s=30.0))
    naive.record_arrival("m", 0.0)
    assert naive.keepalive("m", 5.0) == 30.0

    fc = FleetController(_central(), FleetPolicy.proactive(
        keepalive_s=30.0, downscale_extend_s=60.0))
    fc.record_arrival("m", 0.0)
    # predictor still sees demand inside its window -> full extension
    assert fc.keepalive("m", 5.0) == 90.0
    # window drained, no episode period yet -> back to the base reap
    assert fc.keepalive("m", 500.0) == 30.0


def test_keepalive_stretches_to_predicted_episode():
    fc = FleetController(_central(), FleetPolicy.proactive(
        keepalive_s=10.0, downscale_extend_s=100.0))
    for t0 in (0.0, 60.0):
        _burst(fc, "m", t0)
    # at t=100 the next episode is predicted at 120: the idle window must
    # cover the 20 s gap (plus a pulse) even though the predictor's
    # trailing window is empty by then... but never beyond the cap
    assert fc.keepalive("m", 100.0) >= 20.0
    assert fc.keepalive("m", 100.0) <= 110.0


def test_prewarm_fires_once_then_goes_stale():
    fc = FleetController(_central(), FleetPolicy.proactive(
        prewarm_lead_s=10.0))
    for t0 in (0.0, 100.0, 200.0):
        _burst(fc, "m", t0)
    at_zero = lambda m: True
    assert fc.prewarm_due(280.0, at_zero) == []       # before the window
    plans = fc.prewarm_due(292.0, at_zero)            # inside nxt - lead
    assert len(plans) == 1 and plans[0].model == "m" \
        and plans[0].reason == "prewarm"
    # one prewarm per predicted episode
    assert fc.prewarm_due(293.0, at_zero) == []
    # the predicted episode never arrived: past 1.5 periods of silence
    # the pattern is stale and prewarming stops
    assert fc.prewarm_due(392.0, at_zero) == []


def test_prewarm_respects_at_zero():
    fc = FleetController(_central(), FleetPolicy.proactive(
        prewarm_lead_s=10.0))
    for t0 in (0.0, 100.0):
        _burst(fc, "m", t0)
    assert fc.prewarm_due(195.0, lambda m: False) == []


def test_cold_start_plan_gates_on_capacity():
    c = _central()
    c.register_model(_profile())
    fc = FleetController(c, FleetPolicy.naive())
    assert not fc.cold_start_plan("m", 0, 0, 0, 1.0)
    assert not fc.cold_start_plan("m", 4, 8, 1, 1.0)   # covered in flight
    plan = fc.cold_start_plan("m", 5, 0, 0, 1.0)
    assert plan and plan.n_groups >= 1 and plan.reason == "demand"


def test_demand_rank_orders_hottest_first():
    fc = FleetController(_central(), FleetPolicy.proactive())
    _burst(fc, "cold", 0.0, n=1)
    _burst(fc, "hot", 0.0, n=8)
    rank = fc.demand_rank(1.0)
    assert rank.index("hot") < rank.index("cold")


# ============================================== Alg. 1 model distribution
def test_plan_distribution_fanout_and_skip_seeded():
    servers = {
        "fat": ServerSpec("fat", 32 * Gbps, 12e9, 24 * GB, 1),
        "mid": ServerSpec("mid", 16 * Gbps, 12e9, 24 * GB, 1),
        "thin": ServerSpec("thin", 8 * Gbps, 12e9, 24 * GB, 1),
    }
    c = CentralController(servers)
    new = c.plan_distribution(["a"], fanout=2)
    # fattest NICs first
    assert new == [("a", "fat"), ("a", "mid")]
    for m, sid in new:
        c.record_placement(m, sid)
    # already-seeded pairs are skipped; load balancing spreads the rest
    new2 = c.plan_distribution(["a", "b"], fanout=3)
    assert ("a", "thin") in new2 and ("a", "fat") not in new2
    assert {sid for m, sid in new2 if m == "b"} == set(servers)


def test_plan_cold_start_prefers_seeded_servers():
    c = _central(4)
    c.register_model(_profile(max_pp=2))
    scheme = c.plan_cold_start("m", prefer=["s2", "s3"])
    assert set(scheme.servers) <= {"s2", "s3"}
    # infeasible preferred pool falls back to the open cluster
    tiny = {"s0": ServerSpec("s0", 16 * Gbps, 12e9, 24 * GB, 1),
            "s1": ServerSpec("s1", 16 * Gbps, 12e9, 1, 1)}
    c2 = CentralController(tiny)
    c2.register_model(_profile(max_pp=1))
    scheme2 = c2.plan_cold_start("m", prefer=["s1"])
    assert scheme2.servers == ("s0",)


# ================================================== sim integration (DES)
def _fleet_sim(policy):
    servers = [ServerSpec(f"a10-{i}", 16 * Gbps, 12e9, 24 * GB, 1)
               for i in range(4)]
    profiles = {n: ModelProfile(n, w.size_bytes, timings_for(n),
                                SLO(7.5, 0.2),
                                kv_bytes_per_token=kv_bytes_for(n))
                for n, w in WARM.items()}
    insts = make_instances(APPLICATIONS[:2], 2)
    sim = ServerlessSim(servers, profiles, insts, system="hydra",
                        keepalive_s=20.0, policy=policy)
    reqs = periodic_bursts(insts, 90.0, 4, 2, stagger=3.0, seed=1)
    sim.submit(reqs)
    sim.run(until=90.0 * 6)
    m = sim.metrics()
    assert m["n"] == len(reqs)
    return m


def test_sim_proactive_policy_prewarms_and_improves():
    naive = _fleet_sim(FleetPolicy.naive(keepalive_s=20.0))
    pro = _fleet_sim(FleetPolicy.proactive(
        keepalive_s=20.0, downscale_extend_s=30.0,
        placement_interval_s=20.0))
    assert naive["prewarms"] == 0 and naive["placements"] == 0
    assert pro["prewarms"] > 0 and pro["placements"] > 0
    assert pro["cold_requests"] < naive["cold_requests"]


# ========================================== real-JAX fleet frontend
@pytest.fixture(scope="module")
def tiny_cfg():
    return ModelConfig(name="fleet-tiny", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                       vocab=128, dtype="float32", max_pp=2)


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return build_model(tiny_cfg).init(jax.random.PRNGKey(0))


def _fleet(policy, n_servers=2, nic=10 * Gbps, **kw):
    servers = [ServerSpec(f"s{i}", nic, 12e9, 2 * GB, 1)
               for i in range(n_servers)]
    return FleetFrontend(servers, policy, **kw)


def _register(ff, name, cfg, params=None, size=2 * 1024 * 1024, **kw):
    prof = ModelProfile(name, size, T, SLO(10.0, 0.5), max_pp=2,
                        kv_bytes_per_token=256)
    return ff.register(cfg, prof, params=params, max_batch=2, max_seq=64,
                       **kw)


def test_fleet_scale_to_zero_bit_exact(tiny_cfg, tiny_params):
    ff = _fleet(FleetPolicy.naive(keepalive_s=15.0))
    for i in range(2):
        _register(ff, f"m{i}", tiny_cfg, tiny_params)
    trace = [(f"m{i}", t, [3 + i, 5, 7]) for i in range(2)
             for t in (0.0, 60.0)]
    reqs = ff.run_trace(trace, drain_to=110.0)
    first = {r.model: r.output for r in reqs if r.arrival == 0.0}
    for r in reqs:
        assert r.output, f"{r.model}@{r.arrival} never served"
        if r.arrival == 60.0:
            assert r.output == first[r.model], "re-warm diverged"
    # both bursts were cold (the 15 s keepalive reaped between them) and
    # the pool is back at zero after the final drain
    assert ff.metrics()["cold_starts"] == 4
    assert all(not mm.slots for mm in ff.models.values())


def test_fleet_queued_requests_flush_at_ready(tiny_cfg, tiny_params):
    ff = _fleet(FleetPolicy.naive(keepalive_s=30.0))
    _register(ff, "m0", tiny_cfg, tiny_params)
    r1 = ff.submit("m0", [3, 5], now=0.0)
    # second request lands mid cold start: it must queue, not relaunch
    dur = ff.cold_start_log[0]["duration"]
    assert dur > 0.1
    r2 = ff.submit("m0", [3, 5], now=dur / 2)
    assert len(ff.cold_start_log) == 1
    assert r1.cold and r2.cold
    ff.advance(dur + 1.0)                   # endpoint ready: queue flushes
    assert r1.wait == pytest.approx(dur, rel=0.1)
    assert r2.wait == pytest.approx(dur / 2, rel=0.2)
    assert r2.output == r1.output


def test_fleet_concurrent_cold_starts_contend(tiny_cfg, tiny_params):
    """Two models launched the same instant on a small pool finish later
    than a model launched alone: their stage fetches share NICs."""
    nic = 1e5          # thin NIC: the fetch dominates and must be shared
    solo = _fleet(FleetPolicy.naive(), n_servers=1, nic=nic)
    _register(solo, "m0", tiny_cfg, tiny_params)
    solo.run_trace([("m0", 0.0, [3, 5])])
    alone = solo.cold_start_log[0]["duration"]

    both = _fleet(FleetPolicy.naive(), n_servers=1, nic=nic)
    for i in range(2):
        _register(both, f"m{i}", tiny_cfg, tiny_params)
    both.run_trace([("m0", 0.0, [3, 5]), ("m1", 0.0, [4, 6])])
    durs = sorted(c["duration"] for c in both.cold_start_log)
    assert len(durs) == 2
    assert durs[-1] > alone * 1.2   # the shared NIC slowed someone down


def test_fleet_cold_deploy_from_disk(tiny_cfg, tiny_params, tmp_path):
    from repro.store.store import ModelStore
    m = build_model(tiny_cfg)
    ModelStore.save(str(tmp_path), m, tiny_params,
                    peer_bw=None, remote_bw=None)

    live = _fleet(FleetPolicy.naive())
    _register(live, "m0", tiny_cfg, tiny_params)
    a = live.run_trace([("m0", 0.0, [3, 5, 7])])

    cold = _fleet(FleetPolicy.naive())
    _register(cold, "m0", tiny_cfg, params=None, store_dir=str(tmp_path))
    b = cold.run_trace([("m0", 0.0, [3, 5, 7])])
    assert b[0].output == a[0].output   # no live tree ever touched


def test_fleet_placement_accelerates_cold_start(tiny_cfg, tiny_params):
    """After an Alg. 1 placement round the next cold start fetches from
    the placed fast tier instead of the slow source registry."""
    policy = FleetPolicy(keepalive_s=5.0, proactive_placement=True,
                         placement_interval_s=10.0, placement_top_k=2)
    ff = _fleet(policy, source_bw=1e4, placement_bw=1e9)
    _register(ff, "m0", tiny_cfg, tiny_params)
    ff.submit("m0", [3, 5], now=0.0)        # slow cold start, seeds demand
    slow = ff.cold_start_log[0]
    ff.advance(slow["ready"] + 20.0)        # placement round + reap
    assert ff.placement_log, "placement round never ran"
    assert not ff.models["m0"].slots
    ff.submit("m0", [3, 5], now=ff.now)
    fast = ff.cold_start_log[-1]
    assert fast["tier"] == policy.placement_tier
    assert fast["duration"] < slow["duration"] / 10


# ====================================== KV-aware routing (repro/router/)
def _session_trace(n_sessions=3, turns=3, vocab=128):
    """Growing-prefix multi-turn prompts (in-vocab token ids)."""
    out = []
    for s in range(n_sessions):
        base = [(s * 17 + j) % vocab for j in range(16)]
        for k in range(turns):
            out.append(base + [(s * 31 + 7 * k + j) % vocab
                               for j in range(8 * k)])
    return out


def _routed_fleet(tiny_cfg, tiny_params, routing, n_replicas):
    from repro.serving.api import SamplingParams
    ff = _fleet(FleetPolicy.naive(keepalive_s=1e6))
    _register(ff, "m0", tiny_cfg, tiny_params, block_size=8,
              routing=routing)
    ff.scale_to("m0", n_replicas, now=0.0)
    mm = ff.models["m0"]
    t = max(s.ready_at for s in mm.slots) + 1.0
    reqs = []
    for prompt in _session_trace():
        reqs.append(ff.submit("m0", prompt, SamplingParams(max_new=3),
                              now=t))
        t += 0.5
    ff.advance(t + 5.0)
    return ff, reqs


def test_fleet_routed_outputs_bit_exact_and_affinity_wins(tiny_cfg,
                                                          tiny_params):
    """The routed replica never changes the decoded tokens, and warm-
    prefix affinity strictly beats round-robin on cached tokens (and
    therefore TTFT p99) on a multi-turn session trace."""
    ref_ff, ref = _routed_fleet(tiny_cfg, tiny_params, "kv_affinity", 1)
    rr_ff, rr = _routed_fleet(tiny_cfg, tiny_params, "round_robin", 2)
    aff_ff, aff = _routed_fleet(tiny_cfg, tiny_params, "kv_affinity", 2)
    want = [r.output for r in ref]
    assert [r.output for r in rr] == want
    assert [r.output for r in aff] == want
    assert all(r.replica for r in aff)
    rr_m = rr_ff.metrics()["per_model"]["m0"]
    aff_m = aff_ff.metrics()["per_model"]["m0"]
    assert aff_m["cached_tokens"] > rr_m["cached_tokens"]
    assert aff_m["cached_ratio"] > rr_m["cached_ratio"]
    p99 = lambda reqs: sorted(r.ttft for r in reqs)[-1]
    assert p99(aff) < p99(rr)
    # per-model metrics expose the router + tier sections
    assert aff_m["router"]["policy"] == "kv_affinity"
    assert aff_m["router"]["decisions"] == len(aff)
    assert set(aff_m["endpoints"]) == {"m0/r0", "m0/r1"}
    assert "host_blocks" in aff_m["kv_tier"]


def test_fleet_scale_to_zero_spills_and_restores(tiny_cfg, tiny_params):
    """Reaping a routed model demotes its prefix cache to the host tier;
    the next cold start restores it instead of re-prefilling, bit-exact
    with the first pass."""
    from repro.serving.api import SamplingParams
    ff = _fleet(FleetPolicy.naive(keepalive_s=1e6))
    _register(ff, "m0", tiny_cfg, tiny_params, block_size=8,
              routing="kv_affinity")
    ff.scale_to("m0", 1, now=0.0)
    mm = ff.models["m0"]
    ready = max(s.ready_at for s in mm.slots)
    P = list(range(1, 17))
    r1 = ff.submit("m0", P, SamplingParams(max_new=4), now=ready + 1.0)
    ff.fleet.policy.keepalive_s = 1.0       # now let the reaper run
    ff.advance(ready + 400.0)
    assert not mm.slots, "keepalive reap never fired"
    assert mm.kv_tier.host_blocks > 0       # cache spilled, not discarded
    r2 = ff.submit("m0", P, SamplingParams(max_new=4), now=ready + 500.0)
    ff.advance(ready + 900.0)
    assert r2.output == r1.output
    assert r2.restored_tokens > 0
    assert r2.restore_seconds > 0.0
    assert mm.kv_tier.restores > 0
