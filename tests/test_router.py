"""KV-aware routing subsystem (repro/router/).

Four layers of guarantees:
  * eviction notifications — ``BlockManager`` fires ``evict_hooks``
    *synchronously at* eviction, before the freed block id can be
    reused, so a spill hook reads the page bytes the evicted chain hash
    actually names (the silent-eviction regression);
  * residency — ``ResidencyIndex`` mirrors each engine's prefix index
    exactly under churn, eviction and consolidation, and its
    ``match()`` agrees with what an allocation would find;
  * spill/restore — refcount-zero evicted blocks round-trip through the
    host and segment tiers bit-exactly, into the same engine or a
    different replica of the model, with the transfer accounted as a
    measured flow;
  * routing — policy units (affinity beats round-robin on multi-turn
    sessions, saturation overflows to least-loaded) and the fleet-level
    invariant that the routed replica never changes the decoded tokens.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.router import (KVAffinityPolicy, KVBlockStore, LeastLoadedPolicy,
                          ReplicaView, ResidencyIndex, RoundRobinPolicy,
                          Router, make_routing_policy)
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine
from repro.serving.kvcache import BlockManager

VOCAB = 128
PREFIX = list(range(1, 17))                      # 2 blocks at block_size=8


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="router-tiny", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      vocab=VOCAB, dtype="float32", max_pp=2)
    return cfg, build_model(cfg).init(jax.random.PRNGKey(0))


def _engine(cfg, stage_params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("block_size", 8)
    kw.setdefault("paged", True)
    kw.setdefault("prefix_cache", True)
    return Engine(cfg, stage_params, **kw)


def _churn(eng, seed, n=1):
    """Distinct throwaway prompts that push the LRU cache out."""
    for i in range(n):
        q = [(seed + 13 * i + j) % VOCAB for j in range(24)]
        eng.submit(q, SamplingParams(max_new=2))
        eng.run()


# ---------------------------------------------------------------------------
# BlockManager notifications (the silent-eviction regression)
# ---------------------------------------------------------------------------

def test_evict_hook_fires_before_block_reuse():
    """The hook must see the (block, hash) pair while the block still
    holds that hash's content — i.e. before ``_take_block`` hands the id
    out for overwriting — and the hash must already be unregistered so a
    concurrent lookup cannot ref a dying block."""
    bm = BlockManager(n_blocks=4, block_size=4, bytes_per_token=2,
                      prefix_cache=True)
    events = []

    def on_evict(blk, h):
        events.append(("evict", blk, h))
        assert h not in bm._index            # unregistered first...
        assert bm._ref[blk] == 0             # ...and nobody holds it

    bm.evict_hooks.append(on_evict)
    bm.commit_hooks.append(lambda blk, h: events.append(("commit", blk, h)))

    t1 = bm.allocate(1, 16, list(range(16)))     # fills the pool
    for i in range(4):
        bm.commit(1, (i + 1) * 4)
    bm.free(1)                               # 4 cached, refcount-zero blocks
    assert [e[0] for e in events] == ["commit"] * 4
    committed = {e[1]: e[2] for e in events}

    t2 = bm.allocate(2, 16, list(range(100, 116)))   # must evict all four
    evicts = [e for e in events if e[0] == "evict"]
    assert {e[1] for e in evicts} == set(committed)
    assert {e[2] for e in evicts} == set(committed.values())
    # every reused block id was announced as evicted before reuse
    assert set(t2.blocks) <= {e[1] for e in evicts}
    assert t1 is not None and t2 is not None


def test_spill_hook_reads_pre_reuse_content(tiny):
    """Engine-level regression: the spilled payload equals the page
    content captured at commit time, even though the block is reused by
    the very allocation that evicted it."""
    cfg, params = tiny
    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    r = eng.submit(PREFIX, SamplingParams(max_new=2))
    eng.run()
    bm = eng.block_mgr
    want = {h: eng.runner.read_pages(bm._index[h])
            for h in bm.indexed_hashes()}
    _churn(eng, seed=50, n=12)               # evict PREFIX's blocks
    for h, ref_payload in want.items():
        assert tier.has(h), "committed block vanished without spilling"
        got = tier._host[h]
        for (n1, k1, v1), (n2, k2, v2) in zip(got, ref_payload):
            assert n1 == n2
            assert np.array_equal(np.asarray(k1), np.asarray(k2))
            assert np.array_equal(np.asarray(v1), np.asarray(v2))


def test_drop_unreferenced_cache_spills(tiny):
    """Scale-to-zero's cache drop demotes every cached block to the
    tier instead of discarding it."""
    cfg, params = tiny
    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    eng.submit(PREFIX, SamplingParams(max_new=2))
    eng.run()
    n_cached = eng.block_mgr.n_cached
    assert n_cached >= 2
    eng.block_mgr.drop_unreferenced_cache()
    assert tier.host_blocks == n_cached


# ---------------------------------------------------------------------------
# Residency index
# ---------------------------------------------------------------------------

def test_residency_exact_under_churn(tiny):
    cfg, params = tiny
    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    rng = np.random.default_rng(3)
    for i in range(10):
        n = int(rng.integers(4, 30))
        q = [int(x) for x in rng.integers(0, VOCAB, n)]
        eng.submit(q, SamplingParams(max_new=2))
        eng.run()
        assert res.resident_hashes("r0") == \
            set(eng.block_mgr.indexed_hashes()), f"diverged at round {i}"


def test_residency_match_counts_warm_and_restorable(tiny):
    cfg, params = tiny
    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    eng.submit(PREFIX, SamplingParams(max_new=2))
    eng.run()
    assert res.match("r0", PREFIX) == (2, 0)         # both blocks warm
    i = 0
    while res.match("r0", PREFIX)[0] > 0:
        _churn(eng, seed=200 + 17 * i)
        i += 1
        assert i < 60
    warm, restorable = res.match("r0", PREFIX)
    assert warm == 0 and restorable == 2             # both spilled
    # detach stops mirroring (and late-attach seeds from the live index)
    res.detach("r0")
    _churn(eng, seed=900)
    res2 = ResidencyIndex(kv_tier=tier)
    res2.attach("r0", eng.block_mgr)
    assert res2.resident_hashes("r0") == \
        set(eng.block_mgr.indexed_hashes())


def test_residency_survives_consolidation(tiny):
    """§6.2 swaps the engine but carries the BlockManager — the attached
    residency hooks keep firing on the successor."""
    cfg, params = tiny
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    tier = KVBlockStore()
    eng = _engine(cfg, sp, kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    r = eng.submit(PREFIX, SamplingParams(max_new=4))
    eng.run()
    want = list(r.generated)
    eng2 = eng.consolidated(params)
    assert res.resident_hashes("r0") == set(eng2.block_mgr.indexed_hashes())
    _churn(eng2, seed=400, n=12)                     # successor evictions...
    assert res.resident_hashes("r0") == set(eng2.block_mgr.indexed_hashes())
    r2 = eng2.submit(PREFIX, SamplingParams(max_new=4))
    eng2.run()
    assert list(r2.generated) == want                # ...spilled + restored


# ---------------------------------------------------------------------------
# Spill / restore
# ---------------------------------------------------------------------------

def test_spill_restore_bit_exact_same_engine(tiny):
    cfg, params = tiny
    ref = _engine(cfg, [params])
    want = ref.submit(PREFIX, SamplingParams(max_new=6))
    ref.run()

    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    r1 = eng.submit(PREFIX, SamplingParams(max_new=6))
    eng.run()
    assert list(r1.generated) == list(want.generated)
    i = 0
    while res.match("r0", PREFIX)[0] > 0:
        _churn(eng, seed=600 + 29 * i)
        i += 1
        assert i < 60
    r2 = eng.submit(PREFIX, SamplingParams(max_new=6))
    eng.run()
    assert list(r2.generated) == list(want.generated)
    assert r2.metrics.restored_tokens > 0
    assert r2.metrics.restore_seconds > 0.0
    assert tier.restores > 0 and tier.restored_bytes > 0


def test_spill_restore_bit_exact_cross_replica(tiny):
    """Content-addressed payloads restore into a different replica's
    pool (fresh engine, same weights, shared tier)."""
    cfg, params = tiny
    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("a", eng.block_mgr)
    r1 = eng.submit(PREFIX, SamplingParams(max_new=6))
    eng.run()
    i = 0
    while res.match("a", PREFIX)[0] > 0:
        _churn(eng, seed=700 + 31 * i)
        i += 1
        assert i < 60
    eng2 = _engine(cfg, [params], kv_tier=tier)
    r2 = eng2.submit(PREFIX, SamplingParams(max_new=6))
    eng2.run()
    assert list(r2.generated) == list(r1.generated)
    assert r2.metrics.restored_tokens > 0


def test_host_capacity_demotes_to_segment_tier(tiny):
    """A bounded host tier pushes its LRU overflow into the serialized
    segment store; a segment restore is still bit-exact and charged at
    the segment tier's (slower) bandwidth."""
    cfg, params = tiny
    tier = KVBlockStore(host_capacity_blocks=1)
    eng = _engine(cfg, [params], kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    eng.submit(PREFIX, SamplingParams(max_new=2))
    eng.run()
    ref = _engine(cfg, [params])
    want = ref.submit(PREFIX, SamplingParams(max_new=6))
    ref.run()
    i = 0
    while res.match("r0", PREFIX)[0] > 0:
        _churn(eng, seed=800 + 37 * i)
        i += 1
        assert i < 60
    assert tier.demotions > 0
    assert tier.host_blocks <= 1
    hashes = res.chain_hashes("r0", PREFIX)
    assert any(tier.tier_of(h) == "segment" for h in hashes)
    seg_rate = tier.restore_rate(next(h for h in hashes
                                      if tier.tier_of(h) == "segment"))
    assert seg_rate <= tier.segments.bandwidth < tier.host_bw
    r2 = eng.submit(PREFIX, SamplingParams(max_new=6))
    eng.run()
    assert list(r2.generated) == list(want.generated)


def test_restore_accounted_as_measured_flow(tiny):
    """Each restore is a flow on the shared schedule whose measured
    seconds match the analytic quote under no contention."""
    cfg, params = tiny
    tier = KVBlockStore()
    eng = _engine(cfg, [params], kv_tier=tier)
    res = ResidencyIndex(kv_tier=tier)
    res.attach("r0", eng.block_mgr)
    eng.submit(PREFIX, SamplingParams(max_new=2))
    eng.run()
    i = 0
    while res.match("r0", PREFIX)[0] > 0:
        _churn(eng, seed=340 + 41 * i)
        i += 1
        assert i < 60
    hashes = res.chain_hashes("r0", PREFIX)
    quote = tier.restore_estimate(hashes, now=0.0)
    assert 0.0 < quote < float("inf")
    eng.submit(PREFIX, SamplingParams(max_new=1))
    eng.run()
    measured = sum(f.seconds for f in tier.restore_flows)
    assert measured == pytest.approx(quote, rel=0.05)
    assert sum(f.size for f in tier.restore_flows) == tier.restored_bytes


# ---------------------------------------------------------------------------
# Routing policies (pure units)
# ---------------------------------------------------------------------------

def _view(name, warm=0, restorable=0, waiting=0, running=0, pending=False):
    return ReplicaView(name, warm, restorable, 8,
                       {"waiting": waiting, "preempted": 0,
                        "running": running}, pending=pending)


def test_affinity_prefers_warm_replica_round_robin_ignores_it():
    views = [_view("a", warm=4), _view("b", warm=0)]
    aff = KVAffinityPolicy()
    assert all(aff.choose(views).name == "a" for _ in range(4))
    rr = RoundRobinPolicy()
    assert [rr.choose(views).name for _ in range(4)] == ["a", "b", "a", "b"]


def test_affinity_discounts_restorable_blocks():
    aff = KVAffinityPolicy(restore_frac=0.5)
    warm = _view("w", warm=2)
    cold_restorable = _view("r", restorable=3)
    assert aff.score(warm) > aff.score(cold_restorable)     # 16 > 12
    assert aff.choose([warm, cold_restorable]).name == "w"
    # but restorable still beats a stone-cold replica
    assert aff.choose([cold_restorable, _view("z")]).name == "r"


def test_affinity_overflows_at_saturation_threshold():
    aff = KVAffinityPolicy(saturation_queue=4)
    hot = _view("hot", warm=8, waiting=4)     # at threshold: saturated
    idle = _view("idle")
    assert aff.choose([hot, idle]).name == "idle"
    hot_ok = _view("hot", warm=8, waiting=3)  # below threshold: sticky
    assert aff.choose([hot_ok, idle]).name == "hot"
    # everyone saturated: fall back to least-loaded overall
    busy = _view("busy", waiting=5, running=2)
    assert aff.choose([hot, busy]).name == "hot"
    # a pending cold start counts as saturated regardless of queue
    pend = _view("pend", warm=8, pending=True)
    assert aff.choose([pend, idle]).name == "idle"


def test_least_loaded_and_policy_factory():
    ll = LeastLoadedPolicy()
    assert ll.choose([_view("a", waiting=2), _view("b", running=1)]).name \
        == "b"
    assert isinstance(make_routing_policy("kv_affinity"), KVAffinityPolicy)
    custom = KVAffinityPolicy(saturation_queue=9)
    assert make_routing_policy(custom) is custom
    with pytest.raises(ValueError, match="unknown routing policy"):
        make_routing_policy("warmest_first")


def test_router_routes_and_records_decisions(tiny):
    cfg, params = tiny
    tier = KVBlockStore()
    router = Router("kv_affinity", kv_tier=tier)

    class _Ep:                                   # endpoint shim
        def __init__(self, eng):
            self.engine = eng

        def stats(self):
            return self.engine.stats()

    engines = {n: _engine(cfg, [params], kv_tier=tier) for n in ("a", "b")}
    for n, e in engines.items():
        router.register(n, _Ep(e))
    engines["a"].submit(PREFIX, SamplingParams(max_new=2))
    engines["a"].run()
    d = router.route(PREFIX)
    assert d.name == "a" and d.warm_blocks == 2 and not d.overflowed
    d2 = router.route([99, 98, 97, 96, 95, 94, 93, 92])   # cold everywhere
    assert d2.warm_blocks == 0
    s = router.stats()
    assert s["policy"] == "kv_affinity" and s["decisions"] == 2
    assert s["replicas"] == ["a", "b"]
    router.unregister("b")
    assert router.replicas() == ["a"]


# ---------------------------------------------------------------------------
# Engine / endpoint stats
# ---------------------------------------------------------------------------

def test_engine_stats_shape(tiny):
    cfg, params = tiny
    eng = _engine(cfg, [params])
    r = eng.submit(PREFIX, SamplingParams(max_new=3))
    s0 = eng.stats()
    assert s0["waiting"] == 1 and s0["running"] == 0
    eng.run()
    s1 = eng.stats()
    assert s1["waiting"] == 0 and s1["running"] == 0
    assert s1["steps"] > 0 and s1["free_slots"] == 2
    assert s1["total_blocks"] >= s1["free_blocks"] > 0
    assert r.done
