import os
import sys
from pathlib import Path

# kernels dispatch to the jnp reference on CPU; tests that want interpret
# mode set it explicitly. (Do NOT set XLA device-count flags here — smoke
# tests and benches must see the single real device.)
os.environ.setdefault("REPRO_KERNEL_BACKEND", "ref")

try:                                     # real hypothesis when installed...
    import hypothesis  # noqa: F401
except ImportError:                      # ...else the deterministic shim
    sys.path.insert(0, str(Path(__file__).resolve().parent / "_vendor"))

import dataclasses

import jax
import pytest

from repro.configs import get_config, smoke_variant


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def smoke(name: str, **overrides):
    cfg = smoke_variant(get_config(name))
    if cfg.is_moe and "capacity_factor" not in overrides:
        # no-drop regime so prefill/decode paths agree exactly
        overrides["capacity_factor"] = float(cfg.n_experts)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


ALL_ARCHS = [
    "granite-3-8b", "internlm2-20b", "starcoder2-7b", "qwen1.5-32b",
    "qwen2-moe-a2.7b", "grok-1-314b", "llava-next-34b", "whisper-small",
    "jamba-v0.1-52b", "rwkv6-1.6b",
]
