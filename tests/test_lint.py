"""Repo lint: rule units, suppression, baseline ratchet, clean tree.

Each rule is pinned on synthetic sources (firing AND non-firing
variants), the ``# repro-lint: allow[...]`` waiver is honored on the
same and the preceding line, the baseline ratchet fails on new findings
and reports stale allowances, and — the PR's acceptance bar — the real
``src/repro`` tree lints clean against the checked-in empty baseline.
"""

import json
import os

import pytest

from repro.analysis import lint
from repro.analysis.lint import lint_file, lint_tree


def _lint_src(tmp_path, source, relpath="serving/engine.py"):
    p = tmp_path / os.path.basename(relpath)
    p.write_text(source)
    return lint_file(str(p), relpath)


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# rule units
# ---------------------------------------------------------------------------


def test_kv_bytes_formula_fires_once_per_chain(tmp_path):
    src = "n = 2 * cfg.n_kv_heads * cfg.head_dim * 4 * n_layers\n"
    fs = _lint_src(tmp_path, src, "roofline/report.py")
    assert _rules(fs) == ["kv-bytes-formula"]     # one, not per inner node


def test_kv_bytes_formula_blessed_sites_exempt(tmp_path):
    src = "n = 2 * cfg.n_kv_heads * cfg.head_dim * 4\n"
    assert _lint_src(tmp_path, src, "models/attention.py") == []
    assert _lint_src(tmp_path, src, "roofline/analytic.py") == []


def test_private_blockmanager_outside_home(tmp_path):
    src = ("x = eng.block_mgr._free.pop()\n"
           "y = bm._index[h]\n"
           "z = self._free\n")                    # unrelated self._free: ok
    fs = _lint_src(tmp_path, src, "serving/engine.py")
    assert _rules(fs) == ["private-blockmanager"] * 2
    assert _lint_src(tmp_path, "x = self._free.pop()\n",
                     "serving/kvcache.py") == []


def test_wallclock_and_global_rng_in_sim_scope(tmp_path):
    src = ("t = time.time()\n"
           "r = random.random()\n"
           "g = random.Random(7)\n"               # seeded factory: ok
           "k = jax.random.PRNGKey(0)\n")         # ok
    fs = _lint_src(tmp_path, src, "fleet/controller.py")
    assert _rules(fs) == ["wallclock-in-sim"] * 2
    # outside the sim scope the same calls are fine
    assert _lint_src(tmp_path, src, "launch/bench.py") == []


def test_runtime_assert_scope(tmp_path):
    src = "assert x > 0, 'invariant'\n"
    assert _rules(_lint_src(tmp_path, src, "serving/kvcache.py")) == \
        ["runtime-assert"]
    assert _lint_src(tmp_path, src, "roofline/report.py") == []


def test_blanket_except_requires_accounting(tmp_path):
    bad = ("try:\n    f()\nexcept Exception:\n    pass\n")
    good = ("try:\n    f()\nexcept Exception as e:\n"
            "    log.warning('boom %s', e)\n")
    reraise = ("try:\n    f()\nexcept Exception:\n    raise\n")
    rec = ("try:\n    f()\nexcept Exception as e:\n"
           "    out = {'error': str(e)}\n")
    assert _rules(_lint_src(tmp_path, bad)) == ["blanket-except"]
    assert _lint_src(tmp_path, good) == []
    assert _lint_src(tmp_path, reraise) == []
    assert _lint_src(tmp_path, rec) == []


def test_jit_static_shape_needs_waiver(tmp_path):
    bad = "f = jax.jit(step, static_argnums=(1,))\n"
    waived = ("f = jax.jit(  # repro-lint: allow[jit-static-shape]\n"
              "    step, static_argnames=('n',))\n")
    plain = "f = jax.jit(step, donate_argnums=(0,))\n"
    assert _rules(_lint_src(tmp_path, bad)) == ["jit-static-shape"]
    assert _lint_src(tmp_path, waived) == []
    assert _lint_src(tmp_path, plain) == []


def test_suppression_same_and_previous_line(tmp_path):
    same = "assert x  # repro-lint: allow[runtime-assert]\n"
    prev = ("# repro-lint: allow[runtime-assert]\n"
            "assert x\n")
    wrong = "assert x  # repro-lint: allow[blanket-except]\n"
    assert _lint_src(tmp_path, same, "serving/worker.py") == []
    assert _lint_src(tmp_path, prev, "serving/worker.py") == []
    assert _rules(_lint_src(tmp_path, wrong, "serving/worker.py")) == \
        ["runtime-assert"]


# ---------------------------------------------------------------------------
# baseline ratchet
# ---------------------------------------------------------------------------


def test_baseline_ratchet(tmp_path, capsys):
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    f = pkg / "x.py"
    f.write_text("try:\n    g()\nexcept Exception:\n    pass\n")
    base = tmp_path / "base.json"

    # no baseline: the finding fails the run
    assert lint.main([str(pkg), "--baseline", str(base)]) == 1
    # freeze, then the same tree passes as baselined
    assert lint.main([str(pkg), "--baseline", str(base),
                      "--write-baseline"]) == 0
    assert json.loads(base.read_text()) == {"x.py::blanket-except": 1}
    assert lint.main([str(pkg), "--baseline", str(base)]) == 0
    # a second finding exceeds the allowance
    f.write_text("try:\n    g()\nexcept Exception:\n    pass\n"
                 "try:\n    h()\nexcept Exception:\n    pass\n")
    assert lint.main([str(pkg), "--baseline", str(base)]) == 1
    # fixing everything reports the stale allowance (ratchet down)
    f.write_text("x = 1\n")
    capsys.readouterr()
    assert lint.main([str(pkg), "--baseline", str(base)]) == 0
    assert "ratchet down" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------


def test_repo_lints_clean_against_empty_baseline():
    """The acceptance bar: src/repro has zero findings and the
    checked-in baseline is empty (nothing grandfathered)."""
    root = os.path.dirname(os.path.dirname(
        os.path.abspath(lint.__file__)))           # src/repro
    findings = lint_tree(root)
    assert findings == [], "\n".join(str(f) for f in findings)
    with open(lint.default_baseline_path()) as f:
        assert json.load(f) == {}


def test_cli_entry_clean():
    assert lint.main([]) == 0
