"""Serving engine integration: continuous batching, pipeline-parallel
execution, scale-down/up consolidation — all must match the single-worker
reference bit-exactly (greedy decoding). Serving goes through the stable
ServingEndpoint handle; consolidation happens in place behind it."""

import jax
import pytest

from conftest import smoke
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine
from repro.serving.kvcache import BlockManager

PROMPTS = [[5, 7, 9, 11], [3, 1, 4, 1, 5, 9, 2], [42] * 6, [8, 6, 7]]


def _endpoint(cfg, stage_params, **kw):
    return ServingEndpoint(Engine(cfg, stage_params, **kw))


def _reference(cfg, params, prompts, max_new=10):
    ep = _endpoint(cfg, [params], max_batch=3, max_seq=64)
    reqs = [ep.submit(p, SamplingParams(max_new=max_new)) for p in prompts]
    ep.run()
    return [r.generated for r in reqs]


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_queueing(granite):
    cfg, params = granite
    ep = _endpoint(cfg, [params], max_batch=2, max_seq=64)  # queue forms
    reqs = [ep.submit(p, SamplingParams(max_new=6)) for p in PROMPTS]
    ep.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 6 for r in reqs)
    bm = ep.engine.block_mgr
    assert bm.free_blocks == bm.n_blocks


@pytest.mark.parametrize("n_stages", [2, 4])
def test_pipeline_matches_reference(granite, n_stages):
    cfg, params = granite
    if cfg.n_periods < n_stages:
        pytest.skip("too few periods")
    m = build_model(cfg)
    ref = _reference(cfg, params, PROMPTS)
    sp = [m.slice_stage_params(params, n_stages, i) for i in range(n_stages)]
    ep = _endpoint(cfg, sp, max_batch=3, max_seq=64)
    reqs = [ep.submit(p, SamplingParams(max_new=10)) for p in PROMPTS]
    ep.run()
    assert [r.generated for r in reqs] == ref


@pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b",
                                  "rwkv6-1.6b", "qwen2-moe-a2.7b"])
def test_consolidation_mid_stream(arch, rng):
    cfg = smoke(arch)
    m = build_model(cfg)
    params = m.init(rng)
    ref = _reference(cfg, params, PROMPTS[:2], max_new=8)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = _endpoint(cfg, sp, max_batch=2, max_seq=48)
    reqs = [ep.submit(p, SamplingParams(max_new=8)) for p in PROMPTS[:2]]
    for _ in range(3):
        ep.step()
    ep.consolidate(params)               # in place: same handle keeps going
    ep.run()
    assert [r.generated for r in reqs] == ref


def test_scale_up_yields_standalone_replicas(granite):
    cfg, params = granite
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = _endpoint(cfg, sp, max_batch=2, max_seq=64)
    r0 = ep.submit(PROMPTS[0], SamplingParams(max_new=6))
    for _ in range(2):
        ep.step()
    endpoints = ep.scale_up(params)
    assert len(endpoints) == 2
    assert endpoints[0] is ep            # the handle survives the swap
    ep.run()
    assert r0.done
    # the new replica serves fresh requests with identical outputs
    r1 = endpoints[1].submit(PROMPTS[0], SamplingParams(max_new=6))
    endpoints[1].run()
    ref = _reference(cfg, params, [PROMPTS[0]], max_new=6)[0]
    assert r1.generated == ref


def test_vlm_prefix_serving(rng):
    import numpy as np
    cfg = smoke("llava-next-34b")
    m = build_model(cfg)
    params = m.init(rng)
    ep = _endpoint(cfg, [params], max_batch=2, max_seq=64)
    prefix = np.random.default_rng(0).standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
    r = ep.submit([3, 5, 7], SamplingParams(max_new=5), prefix_embeds=prefix)
    ep.run()
    assert r.done and len(r.generated) == 5


def test_legacy_submit_path_matches_sampling_params(granite):
    """Thin deprecation path: submit(prompt, int) and submit(max_new=n)
    still work on the raw engine and match SamplingParams exactly."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=3, max_seq=64)
    a = eng.submit(PROMPTS[0], 6)                  # legacy positional int
    b = eng.submit(PROMPTS[0], max_new=6)          # legacy kwarg
    c = eng.submit(PROMPTS[0], SamplingParams(max_new=6))
    eng.run()
    assert a.generated == b.generated == c.generated
    with pytest.raises(TypeError):
        eng.submit(PROMPTS[0], SamplingParams(max_new=6), max_new=6)


def test_block_manager_accounting():
    bm = BlockManager(n_blocks=10, block_size=4, bytes_per_token=8)
    bm.allocate(0, 9)                     # 3 blocks
    assert bm.free_blocks == 7
    bm.extend(0, 3)                       # 12 tokens -> 3 blocks still
    assert bm.free_blocks == 7
    bm.extend(0, 1)                       # 13 tokens -> 4 blocks
    assert bm.free_blocks == 6
    assert bm.migration_bytes([0], n_layers=2) == 4 * 4 * 8 * 2
    bm.free(0)
    assert bm.free_blocks == 10
    with pytest.raises(MemoryError):
        bm.allocate(1, 1000)
