"""Serving engine integration: continuous batching, pipeline-parallel
execution, scale-down/up consolidation — all must match the single-worker
reference bit-exactly (greedy decoding)."""

import jax
import pytest

from conftest import smoke
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.kvcache import BlockManager

PROMPTS = [[5, 7, 9, 11], [3, 1, 4, 1, 5, 9, 2], [42] * 6, [8, 6, 7]]


def _reference(cfg, params, prompts, max_new=10):
    eng = Engine(cfg, [params], max_batch=3, max_seq=64)
    reqs = [eng.submit(p, max_new) for p in prompts]
    eng.run()
    return [r.generated for r in reqs]


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_queueing(granite):
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64)  # queue forms
    reqs = [eng.submit(p, 6) for p in PROMPTS]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 6 for r in reqs)
    assert eng.block_mgr.free_blocks == eng.block_mgr.n_blocks


@pytest.mark.parametrize("n_stages", [2, 4])
def test_pipeline_matches_reference(granite, n_stages):
    cfg, params = granite
    if cfg.n_periods < n_stages:
        pytest.skip("too few periods")
    m = build_model(cfg)
    ref = _reference(cfg, params, PROMPTS)
    sp = [m.slice_stage_params(params, n_stages, i) for i in range(n_stages)]
    eng = Engine(cfg, sp, max_batch=3, max_seq=64)
    reqs = [eng.submit(p, 10) for p in PROMPTS]
    eng.run()
    assert [r.generated for r in reqs] == ref


@pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b",
                                  "rwkv6-1.6b", "qwen2-moe-a2.7b"])
def test_consolidation_mid_stream(arch, rng):
    cfg = smoke(arch)
    m = build_model(cfg)
    params = m.init(rng)
    ref = _reference(cfg, params, PROMPTS[:2], max_new=8)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    eng = Engine(cfg, sp, max_batch=2, max_seq=48)
    reqs = [eng.submit(p, 8) for p in PROMPTS[:2]]
    for _ in range(3):
        eng.step()
    eng = eng.consolidated(params)
    eng.run()
    assert [r.generated for r in reqs] == ref


def test_scale_up_yields_standalone_replicas(granite):
    cfg, params = granite
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    eng = Engine(cfg, sp, max_batch=2, max_seq=64)
    r0 = eng.submit(PROMPTS[0], 6)
    for _ in range(2):
        eng.step()
    engines = eng.scale_up(params)
    assert len(engines) == 2
    engines[0].run()
    assert r0.done
    # the new replica serves fresh requests with identical outputs
    r1 = engines[1].submit(PROMPTS[0], 6)
    engines[1].run()
    ref = _reference(cfg, params, [PROMPTS[0]], max_new=6)[0]
    assert r1.generated == ref


def test_vlm_prefix_serving(rng):
    import numpy as np
    cfg = smoke("llava-next-34b")
    m = build_model(cfg)
    params = m.init(rng)
    eng = Engine(cfg, [params], max_batch=2, max_seq=64)
    prefix = np.random.default_rng(0).standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
    r = eng.submit([3, 5, 7], 5, prefix_embeds=prefix)
    eng.run()
    assert r.done and len(r.generated) == 5


def test_block_manager_accounting():
    bm = BlockManager(n_blocks=10, block_size=4, bytes_per_token=8)
    bm.allocate(0, 9)                     # 3 blocks
    assert bm.free_blocks == 7
    bm.extend(0, 3)                       # 12 tokens -> 3 blocks still
    assert bm.free_blocks == 7
    bm.extend(0, 1)                       # 13 tokens -> 4 blocks
    assert bm.free_blocks == 6
    assert bm.migration_bytes([0], n_layers=2) == 4 * 4 * 8 * 2
    bm.free(0)
    assert bm.free_blocks == 10
    with pytest.raises(MemoryError):
        bm.allocate(1, 1000)
