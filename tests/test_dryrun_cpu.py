"""Dry-run plumbing on the single real CPU device: make_cell lowers and
compiles smoke-scale cells on a (1,1) mesh (the 512-device production run
lives in launch/dryrun.py)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import smoke
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_cpu_mesh
from repro.launch.specs import make_cell, rules_for

TINY_SHAPES = {
    "train": ShapeConfig("train_tiny", "train", 32, 2),
    "prefill": ShapeConfig("prefill_tiny", "prefill", 32, 2),
    "decode": ShapeConfig("decode_tiny", "decode", 32, 2),
}


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen2-moe-a2.7b",
                                  "jamba-v0.1-52b", "rwkv6-1.6b",
                                  "whisper-small", "llava-next-34b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_cell_compiles_cpu(arch, kind):
    cfg = smoke(arch)
    shape = TINY_SHAPES[kind]
    if cfg.family == "vlm" and kind != "decode":
        shape = dataclasses.replace(shape, seq_len=shape.seq_len +
                                    cfg.n_image_tokens)
    mesh = make_cpu_mesh()
    fn, args, in_sh, out_sh, donate = make_cell(cfg, shape, mesh,
                                                remat="none")
    with use_mesh(mesh, rules_for(shape, "baseline", cfg)):
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    assert compiled.cost_analysis() is not None
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
