"""End-to-end serverless simulation: systems behave per the paper."""

import pytest

from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO
from repro.serving.simulation import ServerlessSim
from repro.workloads.applications import (APPLICATIONS, WARM, kv_bytes_for,
                                          timings_for)
from repro.workloads.generator import burst, generate, make_instances


def servers():
    return ([ServerSpec(f"a10-{i}", 16 * Gbps, 12e9, 24 * GB, 1)
             for i in range(4)]
            + [ServerSpec(f"v100-{i}", 16 * Gbps, 12e9, 32 * GB, 4)
               for i in range(4)])


def profiles():
    return {n: ModelProfile(n, w.size_bytes, timings_for(n), SLO(7.5, 0.2),
                            kv_bytes_per_token=kv_bytes_for(n))
            for n, w in WARM.items()}


def _run(system, reqs_kw=None, **kw):
    insts = make_instances(APPLICATIONS, 8)
    sim = ServerlessSim(servers(), profiles(), insts, system=system, **kw)
    reqs = generate(insts, rps=0.4, cv=8.0, duration=400, seed=0,
                    **(reqs_kw or {}))
    sim.submit(reqs)
    sim.run(until=5000)
    return sim, reqs


@pytest.mark.parametrize("system", ["vllm", "serverlessllm", "hydra"])
def test_all_requests_complete(system):
    sim, reqs = _run(system)
    assert len(sim.finished) == len(reqs)
    for r in sim.finished:
        assert r.first_token is not None and r.completion is not None
        assert r.completion >= r.first_token >= r.arrival


def test_hydra_beats_vllm_on_cold_ttft():
    m_v, _ = _run("vllm")
    m_h, _ = _run("hydra")
    assert m_h.metrics()["ttft_mean"] < m_v.metrics()["ttft_mean"]
    assert m_h.metrics()["ttft_p99"] < m_v.metrics()["ttft_p99"]


def test_single_cold_start_matches_predictor():
    """Measured single cold start ~= Eq.5 + prefill terms (idle cluster)."""
    insts = make_instances(APPLICATIONS[:1], 1, slo_scale=100.0)
    sim = ServerlessSim(servers(), profiles(), insts, system="hydra",
                        force_s=1)
    reqs = burst(insts[0], 1)
    sim.submit(reqs)
    sim.run(until=600)
    prof = profiles()["llama2-7b"]
    t = prof.timings
    fetch = prof.size_bytes / (16 * Gbps)
    load = prof.size_bytes / 12e9
    ready = max(t.t_cc + t.t_cu + max(load, t.t_l), fetch)
    prefill = t.t_p * insts[0].mean_prompt / 1024.0
    assert abs(reqs[0].ttft - (ready + prefill)) < 0.2


def test_failure_recovery():
    """A killed worker's requests are re-queued and complete via a fresh
    (pipeline-parallel) cold start."""
    insts = make_instances(APPLICATIONS[:1], 1, slo_scale=100.0)
    sim = ServerlessSim(servers(), profiles(), insts, system="hydra")
    reqs = burst(insts[0], 4)
    sim.submit(reqs)
    sim.sim.at(12.0, lambda: sim.inject_failure(insts[0].name))
    sim.run(until=2000)
    assert sim.failures_injected == 1
    assert all(r.completion is not None for r in reqs)


def test_tpot_attainment_stays_high():
    sim, _ = _run("hydra")
    assert sim.metrics()["tpot_attainment"] > 0.85


def test_keepalive_frees_hbm():
    insts = make_instances(APPLICATIONS[:1], 1, slo_scale=100.0)
    sim = ServerlessSim(servers(), profiles(), insts, system="hydra",
                        keepalive_s=30.0)
    reqs = burst(insts[0], 1)
    sim.submit(reqs)
    sim.run(until=3000)
    total_free = sum(d.hbm_free for s in sim.cluster.servers.values()
                     for d in s.devices)
    total = sum(d.hbm_total for s in sim.cluster.servers.values()
                for d in s.devices)
    assert total_free == total          # everything released after idle
