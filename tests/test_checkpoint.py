"""Checkpoint manager: atomic commit, keep-k, crash-consistent restore."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


@pytest.fixture()
def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.zeros((), jnp.float32)}}


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree, extra={"note": "hi"})
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 7
    assert manifest["extra"]["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_restore_latest(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 5, 3):
        t = jax.tree.map(lambda x: x + s, tree)
        mgr.save(s, t)
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 5
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) + 5)


def test_partial_checkpoint_ignored(tmp_path, tree):
    """A crash mid-write (no manifest committed) must be invisible."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    fake = os.path.join(str(tmp_path), "step_0000000099")
    os.makedirs(fake)
    np.save(os.path.join(fake, "a.npy"), np.zeros(3))  # no manifest.json
    assert mgr.latest_step() == 1
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 1


def test_empty_dir(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    restored, manifest = mgr.restore(tree)
    assert restored is None and manifest is None


def test_adversarial_key_names_round_trip(tmp_path):
    """Regression (ISSUE 5): the old ``"/" -> "__"`` file naming collided
    for leaf keys containing ``__`` — ``{"a__b": x}`` and
    ``{"a": {"b": y}}`` mapped to the same file, silently overwriting one
    leaf with the other. The percent-encoding is injective."""
    tree = {"a__b": jnp.full((3,), 1.0),
            "a": {"b": jnp.full((3,), 2.0)},
            "weird/_%_name": jnp.full((2,), 3.0),
            "uniçode": jnp.full((2,), 4.0)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 1
    # every leaf got its own file
    assert len({e["file"] for e in manifest["leaves"]}) == \
        len(manifest["leaves"]) == 4
    np.testing.assert_array_equal(np.asarray(restored["a__b"]),
                                  np.full((3,), 1.0))
    np.testing.assert_array_equal(np.asarray(restored["a"]["b"]),
                                  np.full((3,), 2.0))


def test_committed_checkpoint_gated_on_manifest(tmp_path, tree):
    """The manifest-present invariant stays the commit gate: a step dir
    that lost its manifest is not a checkpoint, durability (dir fsync)
    notwithstanding."""
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(3, tree)
    assert mgr.all_steps() == [3]
    os.unlink(os.path.join(path, "manifest.json"))
    assert mgr.all_steps() == []
