"""Roofline machinery: loop-aware HLO collective parser + analytic terms."""

import math

from repro.configs import SHAPES, get_config
from repro.roofline import analytic
from repro.roofline.analysis import (Roofline, collective_bytes,
                                     model_flops)

SYNTH_HLO = """
HloModule jit_step

%loop_body.1 (p: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %ar = bf16[8,128]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], bf16[8,128]) tuple(%i, %ar)
}

%loop_cond.1 (p: (s32[], bf16[8,128])) -> pred[] {
  %limit = s32[] constant(40)
  ROOT %cmp = pred[] compare(%i, %limit), direction=LT
}

ENTRY %main (a: bf16[8,128]) -> bf16[8,128] {
  %ag = bf16[16,128]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], bf16[8,128]) while(%init), condition=%loop_cond.1, body=%loop_body.1
  ROOT %out = bf16[8,128] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_multiplies_loop_bodies():
    out = collective_bytes(SYNTH_HLO)
    assert out["all-gather"] == 16 * 128 * 2
    # the all-reduce sits in a body executed 40x
    assert out["all-reduce"] == 40 * 8 * 128 * 2


def test_collective_parser_ignores_done():
    txt = """
ENTRY %main (a: bf16[4,4]) -> bf16[4,4] {
  %s = bf16[4,4] all-reduce-start(%a)
  %d = bf16[4,4] all-reduce-done(%s)
}
"""
    out = collective_bytes(txt)
    assert out["all-reduce"] == 4 * 4 * 2


def test_model_flops_conventions():
    cfg = get_config("granite-3-8b")
    n = cfg.active_param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == 6.0 * n * 256 * 4096
    assert model_flops(cfg, SHAPES["decode_32k"]) == 2.0 * n * 128


def test_moe_uses_active_params():
    moe = get_config("qwen2-moe-a2.7b")
    assert model_flops(moe, SHAPES["train_4k"]) < \
        6.0 * moe.param_count() * 256 * 4096


def test_analytic_flops_close_to_6nd():
    """For a dense model, analytic train flops should be within ~2x of the
    6*N*D convention (4/3 remat factor + attention + vocab head)."""
    cfg = get_config("granite-3-8b")
    shape = SHAPES["train_4k"]
    ours = analytic.step_flops(cfg, shape) * 4.0
    canon = model_flops(cfg, shape)
    assert 0.8 < ours / canon < 2.5, ours / canon


def test_roofline_terms_and_dominance():
    r = Roofline("a", "s", "m", 256, flops_total=197e12 * 256,
                 bytes_per_device=819e9 * 2,
                 coll_bytes_per_device={"all-reduce": 50e9},
                 peak_memory_per_device=1 << 30,
                 model_flops_total=197e12 * 128)
    assert math.isclose(r.compute_s, 1.0)
    assert math.isclose(r.memory_s, 2.0)
    assert math.isclose(r.collective_s, 1.0)
    assert r.dominant == "memory"
    assert math.isclose(r.roofline_fraction, 0.25)
