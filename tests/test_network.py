"""Fair-share NIC fluid model (cluster/cluster.py)."""

import math

from repro.cluster.cluster import Cluster
from repro.cluster.sim import EventSim
from repro.core.types import GB, ServerSpec


def mk():
    sim = EventSim()
    cl = Cluster(sim, [ServerSpec("s0", 2e9, 12e9, 24 * GB)])
    return sim, cl


def test_single_flow_time():
    sim, cl = mk()
    done = []
    cl.start_fetch("s0", 10e9, lambda: done.append(sim.now))
    sim.run()
    assert math.isclose(done[0], 5.0, rel_tol=1e-6)


def test_two_flows_fair_share():
    sim, cl = mk()
    done = {}
    cl.start_fetch("s0", 10e9, lambda: done.__setitem__("a", sim.now))
    cl.start_fetch("s0", 10e9, lambda: done.__setitem__("b", sim.now))
    sim.run()
    # both share 1 GB/s -> 10 s each
    assert math.isclose(done["a"], 10.0, rel_tol=1e-6)
    assert math.isclose(done["b"], 10.0, rel_tol=1e-6)


def test_late_joiner():
    sim, cl = mk()
    done = {}
    cl.start_fetch("s0", 10e9, lambda: done.__setitem__("a", sim.now))
    sim.at(2.5, lambda: cl.start_fetch(
        "s0", 10e9, lambda: done.__setitem__("b", sim.now)))
    sim.run()
    # a: 5GB alone (2.5s), then shares: 5GB left at 1GB/s -> done at 7.5s
    assert math.isclose(done["a"], 7.5, rel_tol=1e-6)
    # b: 2.5..7.5 at 1GB/s (5GB), then full rate for remaining 5GB -> 10.0
    assert math.isclose(done["b"], 10.0, rel_tol=1e-6)


def test_weighted_priority():
    sim, cl = mk()
    done = {}
    cl.start_fetch("s0", 6e9, lambda: done.__setitem__("hi", sim.now),
                   weight=2.0)
    cl.start_fetch("s0", 6e9, lambda: done.__setitem__("lo", sim.now),
                   weight=1.0)
    sim.run()
    assert done["hi"] < done["lo"]


def test_cancel_fetch_releases_bandwidth():
    sim, cl = mk()
    done = {}
    fa = cl.start_fetch("s0", 100e9, lambda: done.__setitem__("a", sim.now))
    cl.start_fetch("s0", 10e9, lambda: done.__setitem__("b", sim.now))
    sim.at(1.0, lambda: cl.cancel_fetch(fa))
    sim.run()
    # b: 1GB in first second, then 9GB at full 2GB/s -> 5.5s
    assert math.isclose(done["b"], 5.5, rel_tol=1e-6)
    assert "a" not in done


def test_zero_byte_fetch_completes_immediately():
    sim, cl = mk()
    done = []
    cl.start_fetch("s0", 0, lambda: done.append(sim.now))
    sim.run()
    assert done == [0.0]
