"""§Perf optimization modes must be bit-compatible with the baselines:
causal-skip blocked attention and append-combine decode."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import smoke
from repro.kernels import ops, ref
from repro.models import build_model


def test_causal_skip_matches_masked_full():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, hq, hkv, hd = 2, 260, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    skip = ref.flash_attention_blocked_skip(q, k, v, q_block=64, kv_block=64)
    full = ref.flash_attention_blocked(q, k, v, causal=True, q_block=64,
                                       kv_block=64)
    assert float(jnp.max(jnp.abs(skip - full))) < 2e-5


def test_causal_skip_grad():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, s, hq, hkv, hd = 1, 96, 2, 1, 16
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    g1 = jax.grad(lambda q: jnp.sum(ref.flash_attention_blocked_skip(
        q, k, v, q_block=32, kv_block=32) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(ref.mha_reference(
        q, k, v, causal=True) ** 2))(q)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 5e-4


@pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b"])
def test_decode_append_matches_scatter(arch, rng):
    cfg = smoke(arch)
    m = build_model(cfg)
    params = m.init(rng)
    batch = m.dummy_inputs(rng, batch=2, seq=10)
    logits0, cache0 = m.prefill(params, batch, max_seq=16)
    tok = jnp.argmax(logits0, -1)[:, None]
    pos = jnp.full((2, 1), 10, jnp.int32)
    try:
        ops.set_decode_mode("scatter")
        l1, c1 = m.decode_step(params, cache0, tok, pos)
        ops.set_decode_mode("append")
        l2, c2 = m.decode_step(params, cache0, tok, pos)
    finally:
        ops.set_decode_mode("scatter")
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32)))) < 1e-5


def test_decode_append_empty_cache(rng):
    """pos=0: no prior tokens — the combine must reduce to pure
    self-attention (l_cache = 0 edge case)."""
    cfg = smoke("granite-3-8b")
    m = build_model(cfg)
    params = m.init(rng)
    cache = m.init_cache(batch=2, max_seq=8)
    tok = jnp.array([[3], [5]], jnp.int32)
    pos = jnp.zeros((2, 1), jnp.int32)
    try:
        ops.set_decode_mode("append")
        l_app, _ = m.decode_step(params, cache, tok, pos)
        ops.set_decode_mode("scatter")
        l_sc, _ = m.decode_step(params, cache, tok, pos)
    finally:
        ops.set_decode_mode("scatter")
    assert jnp.all(jnp.isfinite(l_app))
    assert float(jnp.max(jnp.abs(l_app - l_sc))) < 1e-4
