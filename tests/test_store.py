"""Cold-start data plane: chunked store, tiered fetches, streamed stage
loading, and the measured-vs-analytic timeline contract (ISSUE 5)."""

import itertools
import math

import jax
import numpy as np
import pytest

from conftest import smoke
from repro.core.coldstart import OverlapFlags, worker_timeline
from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO, \
    TimingProfile
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServerlessFrontend, ServingEndpoint
from repro.serving.engine import Engine
from repro.store import (FetchSchedule, ModelStore, StreamedStageLoader,
                         assert_within, crosscheck_stages, load_manifest,
                         save_model)

T = TimingProfile(t_cc=2.0, t_l=2.5, t_cu=0.5, t_n=0.01, t_p=1.5, t_d=0.042)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke("granite-3-8b", n_layers=4)      # 4 periods -> s up to 4
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def disk_store(model_and_params, tmp_path_factory):
    m, params = model_and_params
    d = tmp_path_factory.mktemp("store")
    return ModelStore.save(str(d), m, params)


def _trees_equal(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (pb, lb) in zip(fa, fb):
        assert str(pa) == str(pb)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ================================================================ manifest
def test_manifest_stage_ranges_and_bytes(disk_store, model_and_params):
    m, _ = model_and_params
    man = disk_store.manifest
    assert man.n_periods == m.cfg.n_periods
    assert sorted(man.stage_ranges) == list(range(1, m.cfg.n_periods + 1))
    for s in man.degrees:
        assert man.stage_ranges[s] == m.stage_ranges(s)
        # stage byte ranges must sum to the model's own accounting
        for i in range(s):
            assert disk_store.stage_bytes(s, i) == m.stage_bytes(s, i)
        total = sum(disk_store.stage_bytes(s, i) for i in range(s))
        assert total == disk_store.total_bytes


def test_manifest_survives_reopen(disk_store, tmp_path, model_and_params):
    m, params = model_and_params
    save_model(str(tmp_path), m, params)
    man = load_manifest(str(tmp_path))
    assert man.to_json() == disk_store.manifest.to_json()


def test_block_chunks_are_byte_ranges(disk_store):
    """A stage's slice of a period-stacked chunk is a contiguous byte
    range [p0*row, p1*row) — not the whole tensor."""
    man = disk_store.manifest
    s = 2
    p0, p1 = man.stage_ranges[s][1]
    for sc in man.stage_plan(s, 1):
        if sc.chunk.role == "block":
            rb = sc.chunk.row_bytes
            assert (sc.offset, sc.length) == (p0 * rb, (p1 - p0) * rb)
            assert sc.length < sc.chunk.nbytes


# ============================================================= round trips
@pytest.mark.parametrize("s", [1, 2, 4])
def test_loader_matches_slice_stage_params(disk_store, model_and_params, s):
    m, params = model_and_params
    loader = StreamedStageLoader(disk_store, FetchSchedule.single(2e9))
    for i in range(s):
        sp, rec = loader.load_stage(s, i, worker_id=f"rt{s}-{i}")
        _trees_equal(sp, m.slice_stage_params(params, s, i))
        assert rec.fetched_bytes == disk_store.stage_bytes(s, i)
        assert rec.tensors, "stream record must be tensor-granular"


def test_memory_tier_matches_disk(model_and_params, disk_store):
    m, params = model_and_params
    mem = ModelStore.from_params(m, params)
    ld_m = StreamedStageLoader(mem, FetchSchedule.single(2e9))
    ld_d = StreamedStageLoader(disk_store, FetchSchedule.single(2e9))
    a, _ = ld_m.load_stage(2, 0, worker_id="mem0")
    b, _ = ld_d.load_stage(2, 0, worker_id="dsk0")
    _trees_equal(a, b)


# ================================== measured vs analytic (satellite matrix)
FLAG_MATRIX = [OverlapFlags(p, st, ov) for p, st, ov
               in itertools.product((False, True), repeat=3)]


@pytest.mark.parametrize("s", [1, 2, 4])
@pytest.mark.parametrize("flags", FLAG_MATRIX,
                         ids=lambda f: f"pf{int(f.prefetch)}"
                                       f"-st{int(f.stream)}"
                                       f"-ov{int(f.overlap_load)}")
def test_measured_spans_match_analytic(disk_store, flags, s):
    """The full flag-combination matrix (notably prefetch=False with
    overlap_load=True): StreamedStageLoader's measured spans must match
    worker_timeline's analytic ones within 5% under equal bandwidths,
    for s in {1, 2, 4}."""
    checks = crosscheck_stages(disk_store, s, timings=T, flags=flags,
                               nic_bytes_per_s=1e6, load_bytes_per_s=2e6)
    assert_within(checks, 0.05)
    # the runtime stubs and the fetch span are exact, not just within 5%
    for c in checks:
        for span in ("container", "lib", "cuda", "fetch"):
            assert c.measured.timeline.spans[span] == \
                pytest.approx(c.analytic.spans[span], abs=1e-9)


def test_no_prefetch_waits_for_runtime_init(disk_store):
    """Overlap semantics on the *executed* path: without prefetch the
    measured fetch span starts only after every runtime-init span."""
    for ov in (False, True):
        fl = OverlapFlags(prefetch=False, stream=True, overlap_load=ov)
        loader = StreamedStageLoader(disk_store, FetchSchedule.single(1e6),
                                     T, fl, load_bytes_per_s=2e6)
        _, rec = loader.load_stage(1, 0, worker_id=f"np{ov}")
        tl = rec.timeline
        for stage in ("container", "lib", "cuda"):
            assert tl.spans["fetch"][0] >= tl.spans[stage][1] - 1e-12


def test_no_stream_waits_for_full_fetch(disk_store):
    fl = OverlapFlags(prefetch=True, stream=False, overlap_load=True)
    loader = StreamedStageLoader(disk_store, FetchSchedule.single(1e6),
                                 T, fl, load_bytes_per_s=2e6)
    _, rec = loader.load_stage(1, 0, worker_id="ns")
    first_load = min(t.load_start for t in rec.tensors)
    assert first_load >= rec.timeline.spans["fetch"][1] - 1e-12


# ===================================================== contention (Alg. 2)
def test_concurrent_stage_fetches_contend():
    """Two flows on one NIC fair-share it; the small one finishing frees
    bandwidth that accelerates the big one (Eq. 4 event semantics)."""
    sched = FetchSchedule.single(2e9, server_id="s0")
    a = sched.admit("s0", "small", 2e9, now=0.0)
    b = sched.admit("s0", "big", 6e9, now=0.0)
    sched.resolve(a)
    sched.resolve(b)
    assert a.end == pytest.approx(2.0)       # 2 GB at B/2
    # big: 2 s at 1 GB/s, then the remaining 4 GB at the full 2 GB/s
    assert b.end == pytest.approx(4.0)
    assert b.time_at_bytes(2e9) == pytest.approx(2.0)
    assert b.time_at_bytes(6e9) == pytest.approx(4.0)


def test_idle_server_restarts_clock_for_later_cold_start():
    """Regression: a second cold start on an idle NIC must start its
    fetch at its own `now` (prefetch = fetch at t=0), not be serialized
    behind the first cold start's frozen history."""
    sched = FetchSchedule.single(2e9, server_id="s0")
    sched.transfer("s0", "first", 8e9, now=0.0)      # resolves at t=4
    again = sched.transfer("s0", "second", 2e9, now=0.0)
    assert again.start == pytest.approx(0.0)
    assert again.seconds == pytest.approx(1.0)


def test_second_frontend_cold_start_timeline_consistent(model_and_params,
                                                        tmp_path):
    """Two sequential cold starts through one frontend: both measured
    timelines obey prefetch semantics (fetch span starts at `now`)."""
    m, params = model_and_params
    front = ServerlessFrontend(_servers())
    front.deploy(m.cfg, params, _profile(m.cfg), store_dir=str(tmp_path))
    ep1 = front.cold_start(m.cfg.name, min_stages=2, max_batch=2,
                           max_seq=64)
    ep2 = front.cold_start(m.cfg.name, min_stages=2, max_batch=2,
                           max_seq=64)
    for ep in (ep1, ep2):
        for rec in ep.cold_start_timeline.stages:
            assert rec.timeline.spans["fetch"][0] == pytest.approx(0.0)


def test_tier_cap_binds_below_fair_share():
    sched = FetchSchedule.single(2e9)
    f = sched.transfer("local", "capped", 1e9, cap=0.5e9)
    assert f.seconds == pytest.approx(2.0)   # 1 GB at the 0.5 GB/s tier


def test_slow_remote_tier_is_slower(disk_store):
    def ready(tier):
        loader = StreamedStageLoader(disk_store,
                                     FetchSchedule.single(16 * Gbps), T,
                                     load_bytes_per_s=2e6, tier=tier)
        _, rec = loader.load_stage(1, 0, worker_id=f"t-{tier}")
        return rec.timeline.spans["fetch"][1] - \
            rec.timeline.spans["fetch"][0]

    slow = ModelStore.open(disk_store.tier("local").root,
                           remote_bw=1e6)
    loader = StreamedStageLoader(slow, FetchSchedule.single(16 * Gbps), T,
                                 load_bytes_per_s=2e6, tier="remote")
    _, rec = loader.load_stage(1, 0, worker_id="t-remote")
    remote_fetch = rec.timeline.spans["fetch"][1] - \
        rec.timeline.spans["fetch"][0]
    assert remote_fetch == pytest.approx(disk_store.total_bytes / 1e6)
    assert remote_fetch > ready("local")


# ======================================================== frontend e2e
def _servers():
    return {f"srv{i}": ServerSpec(f"srv{i}", 16 * Gbps, 12e9, 24 * GB)
            for i in range(4)}


def _profile(cfg):
    return ModelProfile(cfg.name, int(12.5 * GB), TimingProfile(),
                        SLO(ttft=7.5, tpot=0.2))


def test_frontend_cold_start_streams_from_disk(tmp_path, model_and_params):
    """Acceptance: first token served through weights streamed from the
    on-disk ModelStore, greedy outputs bit-exact with the in-memory
    engine, and a measured timeline on the endpoint."""
    m, params = model_and_params
    cfg = m.cfg
    front = ServerlessFrontend(_servers())
    front.deploy(cfg, params, _profile(cfg), store_dir=str(tmp_path))
    ep = front.cold_start(cfg.name, min_stages=2, max_batch=2, max_seq=64)
    out = [ev.token for ev in ep.generate([5, 3, 8], SamplingParams(
        max_new=8))]

    ref = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64))
    want = [ev.token for ev in ref.generate([5, 3, 8], SamplingParams(
        max_new=8))]
    assert out == want

    report = ep.cold_start_timeline
    assert report is not None and len(report.stages) == ep.n_stages
    assert report.total_bytes == front.store_of(cfg.name).total_bytes
    for rec in report.stages:
        assert set(rec.timeline.spans) == \
            {"container", "lib", "cuda", "fetch", "load"}
        assert rec.timeline.ready <= report.ready


def test_frontend_in_memory_deploy_equivalent(model_and_params, tmp_path):
    """deploy() without a store_dir goes through the from_params memory
    tier — same engine outputs as the on-disk path."""
    m, params = model_and_params
    cfg = m.cfg

    def run(**deploy_kw):
        front = ServerlessFrontend(_servers())
        front.deploy(cfg, params, _profile(cfg), **deploy_kw)
        ep = front.cold_start(cfg.name, min_stages=2, max_batch=2,
                              max_seq=64)
        return [ev.token for ev in ep.generate([9, 1, 4, 7],
                                               SamplingParams(max_new=6))]

    assert run() == run(store_dir=str(tmp_path))


def test_frontend_consolidate_through_store(model_and_params, tmp_path):
    """§6.2 with the data plane attached: full weights fetched through
    the store, outputs bit-exact across the swap, and the KV migration
    bytes accounted as a real measured transfer."""
    m, params = model_and_params
    cfg = m.cfg
    front = ServerlessFrontend(_servers())
    front.deploy(cfg, params, _profile(cfg), store_dir=str(tmp_path))
    ep = front.cold_start(cfg.name, min_stages=2, max_batch=2, max_seq=64,
                          paged=True)
    req = ep.submit([9, 8, 7], SamplingParams(max_new=8))
    for _ in range(4):
        ep.step()
    front.consolidate(ep, cfg.name)
    ep.run()

    ref = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=64,
                                 paged=True))
    rr = ref.submit([9, 8, 7], SamplingParams(max_new=8))
    ref.run()
    assert req.generated == rr.generated
    assert front.last_full_fetch.fetched_bytes == \
        front.store_of(cfg.name).total_bytes
    assert ep.last_migration_flow is not None
    assert ep.last_migration_flow.size == ep.last_migration_bytes
    assert ep.last_migration_flow.done


def test_full_params_roundtrip(model_and_params, tmp_path):
    m, params = model_and_params
    front = ServerlessFrontend(_servers())
    front.deploy(m.cfg, params, _profile(m.cfg), store_dir=str(tmp_path))
    _trees_equal(front.full_params(m.cfg.name), params)


# ==================================== fleet-scale fairness (N cold starts)
def test_n_concurrent_cold_starts_fair_share_closed_form():
    """N stage fetches admitted together on one NIC: fair sharing gives
    the closed-form staggered completions — smallest first, each later
    flow's finish advanced by the bandwidth the finished ones free."""
    B = 2e9
    sizes = [1e9, 2e9, 4e9, 8e9]
    sched = FetchSchedule.single(B, server_id="s0")
    flows = [sched.admit("s0", f"w{i}", s, now=0.0)
             for i, s in enumerate(sizes)]
    for f in flows:
        sched.resolve(f)
    t, prev, n = 0.0, 0.0, len(sizes)
    for k, (f, s) in enumerate(zip(flows, sizes)):
        t += (n - k) * (s - prev) / B
        assert f.end == pytest.approx(t)
        prev = s
    # completion order is deterministic and by size
    assert [f.end for f in flows] == sorted(f.end for f in flows)
    # byte conservation: the link stays saturated until the last byte,
    # so the last completion is exactly total-bytes / bandwidth
    assert flows[-1].end == pytest.approx(sum(sizes) / B)
    # per-flow conservation via the measured arrival profile
    for f, s in zip(flows, sizes):
        assert f.time_at_bytes(0) == pytest.approx(0.0)
        assert f.time_at_bytes(s) == pytest.approx(f.end)


def test_fair_share_independent_of_admit_order():
    """Admission order within one instant must not change anyone's
    completion (the fluid model depends on state, not call order)."""
    B = 4e9
    sizes = [3e9, 1e9, 2e9]

    def ends(order):
        sched = FetchSchedule.single(B, server_id="s0")
        flows = {}
        for i in order:
            flows[i] = sched.admit("s0", f"w{i}", sizes[i], now=0.0)
        for i in sorted(flows):
            sched.resolve(flows[i])
        return [flows[i].end for i in range(len(sizes))]

    a = ends([0, 1, 2])
    b = ends([2, 0, 1])
    for x, y in zip(a, b):
        assert x == pytest.approx(y)


def test_flows_on_distinct_servers_do_not_contend():
    from repro.core.placement import ContentionTracker
    B = 2e9
    specs = {f"s{i}": ServerSpec(f"s{i}", B, 12e9, 1024 * GB)
             for i in range(3)}
    sched = FetchSchedule(ContentionTracker(specs))
    flows = [sched.admit(f"s{i}", f"w{i}", 2e9, now=0.0) for i in range(3)]
    for f in flows:
        sched.resolve(f)
        assert f.end == pytest.approx(1.0)   # each alone on its own NIC


# ========================================== tier placement (Alg. 1 seeds)
def test_place_alias_tier_reads_identical(model_and_params, tmp_path):
    """A proactive placement serves the exact same bytes — only the
    simulated transfer bandwidth differs."""
    m, params = model_and_params
    store = ModelStore.save(str(tmp_path), m, params,
                            peer_bw=None, remote_bw=None)
    placed = store.place("seed", 256 * Gbps)   # faster than local PCIe
    assert store.has_tier("seed")
    assert store.fastest_tier() is placed
    assert store.tier(None) is placed        # fastest-first ordering
    plan = store.stage_plan(1, 0)
    for sc in plan[:4]:
        a = store.tier("local").read(sc.chunk, 0, sc.length)
        b = store.tier("seed").read(sc.chunk, 0, sc.length)
        assert a == b


def test_place_retunes_and_drop_rules(model_and_params, tmp_path):
    m, params = model_and_params
    store = ModelStore.save(str(tmp_path), m, params,
                            peer_bw=None, remote_bw=None)
    t1 = store.place("seed", 1e9)
    t2 = store.place("seed", 8e9)            # re-place retunes in place
    assert t1 is t2 and t2.bandwidth == 8e9
    with pytest.raises(ValueError):
        store.drop_tier("local")             # still backs the placement
    store.drop_tier("seed")
    assert not store.has_tier("seed")
    with pytest.raises(ValueError):
        store.drop_tier("local")             # never drop the only tier


def test_placed_tier_speeds_up_fetch(model_and_params, tmp_path):
    """The loader fetching from a placed fast tier beats the slow
    authoritative tier (cap binds below the NIC fair share)."""
    m, params = model_and_params
    store = ModelStore.save(str(tmp_path), m, params,
                            local_bw=1e6, peer_bw=None, remote_bw=None)
    store.place("seed", 1e9)

    def fetch_span(tier):
        loader = StreamedStageLoader(store, FetchSchedule.single(16 * Gbps),
                                     T, load_bytes_per_s=12e9, tier=tier)
        _, rec = loader.load_stage(1, 0, worker_id=f"pt-{tier}")
        s = rec.timeline.spans["fetch"]
        return s[1] - s[0]

    assert fetch_span("seed") < fetch_span("local") / 100
