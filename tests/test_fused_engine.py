"""Fused ragged-batch engine step + int8 quantized KV pages.

Covers the tentpole contract: a fused engine serves every step with (at
most) two ragged launches and its greedy token streams are bit-exact
with the legacy paged step at full-precision KV; int8 pools decode
deterministically and every byte account (BlockManager quotes, KV-tier
spill/restore flows, consolidation migration) matches the analytic
``paged_kv_token_bytes`` figure exactly. Also pins the bounded-recompile
satellite: chunked prefill no longer compiles one executable per
(chunk_len, hist_len) pair — paged attention-only prefills ride the
ragged path, whose shapes are bucketed to powers of two."""

import jax
import numpy as np
import pytest

from conftest import smoke
from repro.models.attention import paged_kv_token_bytes
from repro.models.model import build_model
from repro.router import KVBlockStore
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine

PROMPTS = [
    [1, 2, 3, 4, 5, 6, 7],
    [9, 8, 7, 6, 5],
    [3, 1, 4, 1, 5, 9, 2, 6, 5, 3],
    [11, 12, 13],
]


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    return cfg, build_model(cfg).init(jax.random.PRNGKey(0))


def _run(cfg, params, n_stages=1, max_new=6, **kw):
    if n_stages == 1:
        sp = [params]
    else:
        m = build_model(cfg)
        sp = [m.slice_stage_params(params, n_stages, i)
              for i in range(n_stages)]
    eng = Engine(cfg, sp, max_batch=3, max_seq=64, block_size=8,
                 paged=True, **kw)
    reqs = [eng.submit(p, SamplingParams(max_new=max_new)) for p in PROMPTS]
    eng.run()
    return [list(r.generated) for r in reqs], eng


def test_fused_matches_legacy_paged(granite):
    cfg, params = granite
    legacy, _ = _run(cfg, params)
    fused, eng = _run(cfg, params, fused=True)
    assert fused == legacy
    # the fused engine never touched the legacy per-request forwards
    w = eng.workers[0]
    assert w._prefill_fn._cache_size() == 0
    assert w._decode_fn._cache_size() == 0


def test_fused_matches_legacy_chunked_prefix(granite):
    cfg, params = granite
    legacy, _ = _run(cfg, params)
    fused, _ = _run(cfg, params, fused=True, prefill_chunk=4,
                    prefix_cache=True)
    assert fused == legacy


def test_fused_fp16_kv_bit_exact(granite):
    """fp16 KV pages: the pool round-trip quantizes K/V to fp16 but at
    smoke scale greedy streams stay bit-exact with the fp32 pools."""
    cfg, params = granite
    legacy, _ = _run(cfg, params)
    fp16, _ = _run(cfg, params, kv_dtype="float16", fused=True)
    assert fp16 == legacy


def test_int8_engine_deterministic(granite):
    cfg, params = granite
    a, eng = _run(cfg, params, kv_dtype="int8", prefill_chunk=4)
    b, _ = _run(cfg, params, kv_dtype="int8", prefill_chunk=4)
    assert a == b
    assert all(len(s) == 6 for s in a)
    assert eng.fused, "int8 defaults the fused step on"
    assert eng.block_mgr.bytes_per_token == paged_kv_token_bytes(cfg,
                                                                 "int8")


def test_engine_knob_validation(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="fused"):
        Engine(cfg, [params], paged=True, kv_dtype="int8", fused=False)
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, [params], paged=False, fused=True)
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, [params], paged=False, kv_dtype="float16")
    eng = Engine(cfg, [params], paged=True, fused=True)
    with pytest.raises(ValueError, match="prefix_embeds"):
        eng.submit([1, 2], SamplingParams(max_new=2),
                   prefix_embeds=np.zeros((2, cfg.d_model), np.float32))


def test_chunked_prefill_compiles_bounded(granite):
    """The recompile satellite: staggered prompts under chunked prefill
    hit many distinct (chunk_len, hist_len) pairs, but the ragged path's
    power-of-two buckets keep the jit cache O(log max_tokens) — and the
    legacy per-(chunk, hist) prefill executable is never built."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=3, max_seq=64, block_size=8,
                 paged=True, prefill_chunk=4)
    lens = [7, 5, 10, 3, 9, 6]
    for i, n in enumerate(lens):
        eng.submit([20 + i] * n, SamplingParams(max_new=4))
    eng.run()
    w = eng.workers[0]
    assert w._prefill_fn._cache_size() == 0, \
        "paged attention-only prefill must ride the ragged path"
    # buckets seen: prefill chunks pad to 8; mixed/decode batches reach
    # at most 3 slots * tile 8 = 24 -> {8, 16, 32}
    assert w._ragged_fn._cache_size() <= 4, \
        f"ragged executables not bounded: {w._ragged_fn._cache_size()}"


def _churn(eng, seed, n):
    """Distinct throwaway prompts that push the LRU cache out."""
    for i in range(n):
        q = [(seed + 13 * i + j) % 500 for j in range(24)]
        eng.submit(q, SamplingParams(max_new=2))
        eng.run()


def test_int8_spill_restore_bytes_exact(granite):
    """Quantized-KV accounting sweep: every spilled/restored block's
    measured payload bytes (int8 pages + f32 scale/zero leaves) equal
    block_size * paged_kv_token_bytes(int8) * n_attn_layers exactly —
    including blocks demoted through the serialized segment tier."""
    cfg, params = granite
    tier = KVBlockStore(host_capacity_blocks=2)
    eng = Engine(cfg, [params], max_batch=2, max_seq=64, block_size=8,
                 paged=True, prefix_cache=True, kv_dtype="int8",
                 kv_tier=tier)
    first = list(range(1, 17))
    r0 = eng.submit(first, SamplingParams(max_new=2))
    eng.run()
    _churn(eng, seed=50, n=12)                # evict + demote blocks
    per_block = (eng.block_mgr.block_size
                 * paged_kv_token_bytes(cfg, "int8")
                 * eng.n_attn_layers())
    assert tier.spills > 0 and tier.demotions > 0
    assert tier.spilled_bytes == tier.spills * per_block
    for h in list(tier._host):
        assert tier.bytes_of(h) == per_block
    # restore through a prefix hit: bytes measured == analytic quote
    r1 = eng.submit(first, SamplingParams(max_new=2))
    eng.run()
    assert tier.restores > 0
    assert tier.restored_bytes == tier.restores * per_block
    assert r1.generated == r0.generated       # restored KV is bit-exact


def test_int8_consolidation_migration_bytes_exact(granite):
    """2-stage int8 engine consolidates mid-flight: the measured gather
    (quantized pages + scale/zero leaves of every non-target stage)
    equals the BlockManager's analytic migration quote exactly, and the
    streams continue identical to a 1-stage run."""
    cfg, params = granite
    single, _ = _run(cfg, params, kv_dtype="int8", prefill_chunk=4)
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    eng = Engine(cfg, sp, max_batch=3, max_seq=64, block_size=8,
                 paged=True, kv_dtype="int8", prefill_chunk=4)
    reqs = [eng.submit(p, SamplingParams(max_new=6)) for p in PROMPTS]
    for _ in range(4):
        eng.step()
    live = [r.rid for r in eng.active()]
    n_remote = eng.n_attn_layers(migrated_only=True)
    quoted = eng.block_mgr.migration_bytes(live, n_remote)
    unique = len(eng.block_mgr.blocks_of(live))
    per_block = (eng.block_mgr.block_size
                 * paged_kv_token_bytes(cfg, "int8") * n_remote)
    assert quoted == unique * per_block > 0
    eng2 = eng.consolidated(params)
    assert eng2.last_migration_bytes == quoted
    eng2.run()
    assert [list(r.generated) for r in reqs] == single
