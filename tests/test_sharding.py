"""Logical-axis resolution + dedup invariants."""

import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, constrain, resolve,
                                        use_mesh)


def test_resolve_outside_mesh_uses_defaults():
    # singleton physical-axis tuples normalize to the bare name
    assert resolve(("batch", "seq", "embed")) == P("data")
    assert resolve(("embed", "ffn")) == P(None, "model")


def test_resolve_dedupes_physical_axes():
    # act_seq and heads both -> 'model' under train rules: first wins
    with use_mesh(None, {"act_seq": "model"}):
        spec = resolve(("batch", "act_seq", "heads"))
    assert spec == P("data", "model")


def test_rules_dropped_for_missing_axes():
    mesh = jax.make_mesh((1,), ("data",))
    with use_mesh(mesh, None):
        # 'model' axis doesn't exist on this mesh -> mapped to None
        assert resolve(("embed", "ffn")) == P()


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "embed") is x


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(sorted(DEFAULT_RULES)), min_size=1,
                max_size=5))
def test_resolve_never_reuses_axis(names):
    spec = resolve(tuple(names))
    used = []
    for part in spec:
        if part is None:
            continue
        used.extend((part,) if isinstance(part, str) else part)
    assert len(used) == len(set(used))
