"""Algorithm 1 + Eq. 1/2/5 predictor tests."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.parallelism import (predict_tpot, predict_ttft,
                                    predict_ttft_overlapped, select_scheme)
from repro.core.types import GB, Gbps, ModelProfile, ServerSpec, SLO, \
    TimingProfile


def servers(n=8, bw=16 * Gbps, pcie=12e9, hbm=24 * GB):
    return {f"s{i}": ServerSpec(f"s{i}", bw, pcie, hbm) for i in range(n)}


def profile(size_gb=12.5, slo=SLO(7.5, 0.2), **kw):
    return ModelProfile("m", int(size_gb * GB), TimingProfile(**kw), slo)


def test_eq1_hand_computed():
    t = TimingProfile(t_cc=2, t_l=2.5, t_cu=0.5, t_n=0.01, t_p=1.5, t_d=0.04)
    M, s, w = 16e9, 4, 2
    ratios = [1 / 2e9 + 1 / 12e9] * 4
    got = predict_ttft(M, s, w, ratios, t)
    expect = (t.t_c + (M / s) * ratios[0]
              + 1.5 * (4 - 2 + 2 / 4) + 0.01 * 4)
    assert math.isclose(got, expect, rel_tol=1e-9)


def test_eq2_hand_computed():
    t = TimingProfile(t_d=0.04, t_n=0.01)
    assert math.isclose(predict_tpot(1, 1, t), 0.04)
    assert math.isclose(predict_tpot(4, 0, t), 0.04 * 4 + 0.01 * 4)
    assert math.isclose(predict_tpot(4, 4, t), 0.04 * 1 + 0.01 * 4)


def test_eq5_fetch_vs_container_path():
    t = TimingProfile(t_cc=2, t_l=2.5, t_cu=0.5, t_n=0.0, t_p=0.0)
    # huge model: fetch dominates
    got = predict_ttft_overlapped(100e9, 1, 1, [2e9], [1e12], t)
    assert math.isclose(got, 50.0)
    # tiny model: container path dominates
    got = predict_ttft_overlapped(1e9, 1, 1, [2e9], [12e9], t)
    assert math.isclose(got, 2 + 0.5 + 2.5)


def test_larger_s_reduces_fetch_time():
    t = TimingProfile()
    m = 50e9
    prev = None
    for s in (1, 2, 4):
        v = predict_ttft_overlapped(m, s, s, [2e9] * s, [12e9] * s, t)
        if prev is not None:
            assert v < prev
        prev = v


def test_select_scheme_meets_slo():
    prof = profile(12.5)
    srv = servers()
    free = {k: 24 * GB for k in srv}
    eff = {k: 2e9 for k in srv}
    sch = select_scheme(prof, srv, free, eff)
    assert sch.slo_ok
    assert sch.predicted_ttft <= prof.slo.ttft
    assert sch.predicted_tpot <= prof.slo.tpot
    assert len(set(sch.servers)) == sch.s


def test_select_scheme_tight_slo_uses_parallelism():
    # big model + tight TTFT: s must exceed 1
    prof = profile(40.0, slo=SLO(9.0, 0.5))
    srv = servers(n=8, hbm=64 * GB)
    free = {k: 64 * GB for k in srv}
    eff = {k: 2e9 for k in srv}
    sch = select_scheme(prof, srv, free, eff)
    assert sch.s > 1
    assert sch.slo_ok


def test_fallback_prefers_tpot_clean():
    # impossible TTFT: fallback must still satisfy TPOT if possible
    prof = profile(40.0, slo=SLO(0.5, 0.2))
    srv = servers(n=8, hbm=64 * GB)
    free = {k: 64 * GB for k in srv}
    eff = {k: 2e9 for k in srv}
    sch = select_scheme(prof, srv, free, eff)
    assert not sch.slo_ok
    assert sch.predicted_tpot <= prof.slo.tpot


def test_fixed_s_honored():
    prof = profile(12.5, slo=SLO(1e9, 1e9))
    srv = servers()
    free = {k: 24 * GB for k in srv}
    eff = {k: 2e9 for k in srv}
    sch = select_scheme(prof, srv, free, eff, fixed_s=3)
    assert sch.s == 3


def test_contended_servers_excluded():
    prof = profile(12.5)
    srv = servers(n=4)
    free = {k: 24 * GB for k in srv}
    eff = {"s0": 0.0, "s1": 2e9, "s2": 2e9, "s3": 2e9}  # s0 contended out
    sch = select_scheme(prof, srv, free, eff)
    assert "s0" not in sch.servers


@settings(max_examples=50, deadline=None)
@given(
    size=st.floats(1e9, 300e9),
    ttft=st.floats(1.0, 60.0),
    tpot=st.floats(0.05, 0.5),
    n_srv=st.integers(2, 12),
)
def test_scheme_invariants(size, ttft, tpot, n_srv):
    prof = ModelProfile("m", int(size), TimingProfile(),
                        SLO(ttft, tpot), full_hbm_bytes=int(size * 1.2))
    srv = servers(n=n_srv, hbm=int(400e9))
    free = {k: int(400e9) for k in srv}
    eff = {k: 2e9 for k in srv}
    sch = select_scheme(prof, srv, free, eff)
    # invariants: s within bounds, w <= s, distinct servers, predictions
    # consistent with the published equations
    assert 1 <= sch.s <= prof.max_pp
    assert 0 <= sch.w <= sch.s
    assert len(sch.servers) == sch.s
    assert len(set(sch.servers)) == sch.s
    assert math.isclose(sch.predicted_tpot,
                        predict_tpot(sch.s, sch.w, prof.timings),
                        rel_tol=1e-9)
    if sch.slo_ok:
        assert sch.predicted_ttft <= ttft + 1e-9
        assert sch.predicted_tpot <= tpot + 1e-9
