"""Workload generator statistics."""

import numpy as np

from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import burst, generate, make_instances


def test_instance_creation():
    insts = make_instances(APPLICATIONS, 4)
    assert len(insts) == 4 * len(APPLICATIONS)
    assert len({i.name for i in insts}) == len(insts)
    scaled = make_instances(APPLICATIONS, 1, slo_scale=2.0)
    assert scaled[0].slo_ttft == 2 * APPLICATIONS[0].slo.ttft


def test_rate_and_cv():
    insts = make_instances(APPLICATIONS, 8)
    reqs = generate(insts, rps=2.0, cv=4.0, duration=2000, seed=0)
    arr = np.array([r.arrival for r in reqs])
    inter = np.diff(arr)
    rate = len(reqs) / 2000
    assert 1.6 < rate < 2.4
    cv = inter.std() / inter.mean()
    assert 3.0 < cv < 5.0


def test_determinism():
    insts = make_instances(APPLICATIONS, 4)
    a = generate(insts, 1.0, 2.0, 200, seed=5)
    b = generate(insts, 1.0, 2.0, 200, seed=5)
    assert [(r.model, r.arrival) for r in a] == \
        [(r.model, r.arrival) for r in b]


def test_popularity_is_skewed():
    insts = make_instances(APPLICATIONS, 16)
    reqs = generate(insts, rps=2.0, cv=2.0, duration=2000, seed=1)
    counts = {}
    for r in reqs:
        counts[r.model] = counts.get(r.model, 0) + 1
    ordered = sorted(counts.values(), reverse=True)
    # zipf: the head model sees far more traffic than the median
    assert ordered[0] > 5 * max(ordered[len(ordered) // 2], 1)


def test_burst():
    insts = make_instances(APPLICATIONS, 1)
    reqs = burst(insts[0], 30, at=3.0)
    assert len(reqs) == 30
    assert all(r.arrival == 3.0 for r in reqs)
