"""Workload generator statistics."""

import numpy as np

from repro.workloads.applications import APPLICATIONS
from repro.workloads.generator import (burst, generate, make_instances,
                                       multi_turn_sessions)


def test_instance_creation():
    insts = make_instances(APPLICATIONS, 4)
    assert len(insts) == 4 * len(APPLICATIONS)
    assert len({i.name for i in insts}) == len(insts)
    scaled = make_instances(APPLICATIONS, 1, slo_scale=2.0)
    assert scaled[0].slo_ttft == 2 * APPLICATIONS[0].slo.ttft


def test_rate_and_cv():
    insts = make_instances(APPLICATIONS, 8)
    reqs = generate(insts, rps=2.0, cv=4.0, duration=2000, seed=0)
    arr = np.array([r.arrival for r in reqs])
    inter = np.diff(arr)
    rate = len(reqs) / 2000
    assert 1.6 < rate < 2.4
    cv = inter.std() / inter.mean()
    assert 3.0 < cv < 5.0


def test_determinism():
    insts = make_instances(APPLICATIONS, 4)
    a = generate(insts, 1.0, 2.0, 200, seed=5)
    b = generate(insts, 1.0, 2.0, 200, seed=5)
    assert [(r.model, r.arrival) for r in a] == \
        [(r.model, r.arrival) for r in b]


def test_popularity_is_skewed():
    insts = make_instances(APPLICATIONS, 16)
    reqs = generate(insts, rps=2.0, cv=2.0, duration=2000, seed=1)
    counts = {}
    for r in reqs:
        counts[r.model] = counts.get(r.model, 0) + 1
    ordered = sorted(counts.values(), reverse=True)
    # zipf: the head model sees far more traffic than the median
    assert ordered[0] > 5 * max(ordered[len(ordered) // 2], 1)


def test_burst():
    insts = make_instances(APPLICATIONS, 1)
    reqs = burst(insts[0], 30, at=3.0)
    assert len(reqs) == 30
    assert all(r.arrival == 3.0 for r in reqs)


def test_multi_turn_sessions():
    """K-turn chat sessions: every turn's prompt strictly extends the
    previous turn's (the growing shared prefix a KV-aware router
    exploits), arrivals are sorted and monotone within a session, and
    all token ids stay inside the requested vocabulary."""
    inst = make_instances(APPLICATIONS, 1)[0]
    reqs = multi_turn_sessions(inst, n_sessions=5, turns=4,
                               first_prompt=24, turn_tokens=8,
                               vocab=100, seed=7)
    assert len(reqs) == 5 * 4
    assert [r.arrival for r in reqs] == sorted(r.arrival for r in reqs)
    by_session = {}
    for r in reqs:
        by_session.setdefault(r.session, []).append(r)
    assert set(by_session) == set(range(5))
    for sess, rs in by_session.items():
        rs.sort(key=lambda r: r.turn)
        assert [r.turn for r in rs] == [0, 1, 2, 3]
        assert len(rs[0].prompt_ids) == 24
        for prev, nxt in zip(rs, rs[1:]):
            assert nxt.arrival > prev.arrival
            # strict prefix extension by exactly turn_tokens ids
            assert nxt.prompt_ids[:len(prev.prompt_ids)] == prev.prompt_ids
            assert len(nxt.prompt_ids) == len(prev.prompt_ids) + 8
        for r in rs:
            assert r.prompt_tokens == len(r.prompt_ids)
            assert all(0 <= t < 100 for t in r.prompt_ids)
    # determinism
    again = multi_turn_sessions(inst, n_sessions=5, turns=4,
                                first_prompt=24, turn_tokens=8,
                                vocab=100, seed=7)
    assert [(r.session, r.turn, r.arrival, r.prompt_ids) for r in again] \
        == [(r.session, r.turn, r.arrival, r.prompt_ids) for r in reqs]


def test_kv_bytes_per_token_from_geometry():
    """Per-model KV footprint comes from the real geometry — llama2-7b's
    reproduces the 512 KiB/token constant the simulation used to
    hardcode, 13B exceeds it — and a geometry-less profile is now a loud
    registration error instead of a silent fallback."""
    import pytest

    from repro.core.types import GB, ModelProfile, ServerSpec, SLO
    from repro.serving.simulation import ServerlessSim
    from repro.workloads.applications import WARM, kv_bytes_for, timings_for

    assert kv_bytes_for("llama2-7b") == 512 * 1024
    assert kv_bytes_for("llama2-13b") == 2 * 40 * 40 * 128 * 2
    assert kv_bytes_for("llama2-13b") > kv_bytes_for("llama2-7b")

    servers = [ServerSpec("s0", 2e9, 12e9, 64 * GB, 1)]
    insts = make_instances(APPLICATIONS, 2)
    profiles = {n: ModelProfile(
        n, w.size_bytes, timings_for(n), SLO(7.5, 0.2),
        kv_bytes_per_token=None if n == "opt-6.7b" else kv_bytes_for(n))
        for n, w in WARM.items()}
    with pytest.raises(ValueError, match="kv_bytes_per_token"):
        ServerlessSim(servers, profiles, insts)

    good = {n: ModelProfile(n, w.size_bytes, timings_for(n), SLO(7.5, 0.2),
                            kv_bytes_per_token=kv_bytes_for(n))
            for n, w in WARM.items()}
    sim = ServerlessSim(servers, good, insts)
    for inst in insts:
        assert sim._kv_bytes_per_token(inst.name) == \
            kv_bytes_for(inst.base_model)
