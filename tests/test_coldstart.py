"""Worker-level overlapping timeline (Fig. 2 / Fig. 9 semantics)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coldstart import OverlapFlags, group_tpot, group_ttft, \
    worker_timeline
from repro.core.types import TimingProfile

T = TimingProfile(t_cc=2.0, t_l=2.5, t_cu=0.5, t_n=0.01, t_p=1.5, t_d=0.042)


def test_baseline_is_fully_sequential():
    tl = worker_timeline(T, fetch_seconds=6.0, load_seconds=1.0,
                         flags=OverlapFlags.none())
    # cc -> lib -> cuda -> fetch -> load
    assert math.isclose(tl.ready, 2.0 + 2.5 + 0.5 + 6.0 + 1.0)


def test_full_overlap_matches_eq5():
    tl = worker_timeline(T, fetch_seconds=6.0, load_seconds=1.0,
                         flags=OverlapFlags.all())
    expect = max(T.t_cc + T.t_cu + max(1.0, T.t_l), 6.0)
    assert math.isclose(tl.ready, expect)


def test_prefetch_only():
    fl = OverlapFlags(prefetch=True, stream=False, overlap_load=False)
    tl = worker_timeline(T, fetch_seconds=6.0, load_seconds=1.0, flags=fl)
    # fetch starts at 0; load begins after max(runtime_end, fetch_start),
    # completes after fetch ends (no streaming)
    assert math.isclose(tl.ready, max(6.0, 2.0 + 2.5 + 0.5) + 1.0)


@settings(max_examples=80, deadline=None)
@given(fetch=st.floats(0.1, 60.0), load=st.floats(0.05, 10.0))
def test_each_optimization_never_hurts(fetch, load):
    base = worker_timeline(T, fetch, load, OverlapFlags.none()).ready
    pf = worker_timeline(T, fetch, load,
                         OverlapFlags(True, False, False)).ready
    stream = worker_timeline(T, fetch, load,
                             OverlapFlags(True, True, False)).ready
    full = worker_timeline(T, fetch, load, OverlapFlags.all()).ready
    assert pf <= base + 1e-9
    assert stream <= pf + 1e-9
    assert full <= stream + 1e-6 or math.isclose(full, stream, rel_tol=1e-6)


@settings(max_examples=40, deadline=None)
@given(fetch=st.floats(0.1, 60.0), load=st.floats(0.05, 10.0))
def test_no_prefetch_fetch_waits_for_runtime_init(fetch, load):
    """Without prefetch, fetch starts only after the FULL runtime init
    (lib and cuda), in either init order; all spans are well-formed."""
    for overlap in (False, True):
        fl = OverlapFlags(prefetch=False, stream=False, overlap_load=overlap)
        tl = worker_timeline(T, fetch, load, flags=fl)
        runtime_end = max(tl.spans["lib"][1], tl.spans["cuda"][1])
        assert tl.spans["fetch"][0] >= runtime_end - 1e-12
        assert all(s0 <= s1 for s0, s1 in tl.spans.values())
        assert tl.ready >= max(s1 for _, s1 in tl.spans.values()) - 1e-12


def test_group_ttft_full_memory_pipeline():
    ready = (5.0, 6.0, 5.5, 5.8)
    got = group_ttft(ready, s=4, w=4, t=T)
    assert math.isclose(got, 6.0 + T.t_p * 1.0 + T.t_n * 4)


def test_group_tpot_eq2():
    assert math.isclose(group_tpot(1, 1, T), T.t_d)
    assert math.isclose(group_tpot(4, 0, T), T.t_d * 4 + T.t_n * 4)
