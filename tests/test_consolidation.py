"""Consolidation policy + sliding-window predictor (§6.1)."""

import pytest

from repro.core.consolidation import (ConsolidationPolicy,
                                      SlidingWindowPredictor)


def test_predictor_window():
    p = SlidingWindowPredictor(window_s=10.0)
    for t in (0.0, 1.0, 2.0, 9.0):
        p.record("m", t)
    assert p.predicted_next_window("m", 9.5) == 4
    assert p.predicted_next_window("m", 11.5) == 2   # 0,1 expired
    assert p.predicted_next_window("m", 30.0) == 0
    assert p.predicted_next_window("other", 5.0) == 0


def test_plan_scale_down_when_quiet():
    pred = SlidingWindowPredictor(60.0)
    pol = ConsolidationPolicy(pred, per_worker_capacity=8)
    plan = pol.plan("m", queue_len=2, now=0.0, max_pp=4, current_workers=1)
    assert plan.mode == "down"
    assert plan.keep_workers == 1


def test_plan_scale_up_under_burst():
    pred = SlidingWindowPredictor(60.0)
    pol = ConsolidationPolicy(pred, per_worker_capacity=8)
    for i in range(40):
        pred.record("m", i * 0.1)
    plan = pol.plan("m", queue_len=30, now=4.0, max_pp=4, current_workers=0)
    assert plan.mode == "up"
    # (30 queued + 40 predicted) / 8 = 9 workers
    assert plan.keep_workers == 9
    assert sum(plan.group_sizes) >= plan.keep_workers
    assert all(1 <= g <= 4 for g in plan.group_sizes)


def test_required_workers_floor():
    pred = SlidingWindowPredictor(60.0)
    pol = ConsolidationPolicy(pred, per_worker_capacity=8)
    assert pol.required_workers("m", 0, 0.0) == 1


@pytest.mark.parametrize("max_pp", [1, 2, 4])
def test_plan_scale_down_group_is_max_pp(max_pp):
    pol = ConsolidationPolicy(SlidingWindowPredictor(60.0),
                              per_worker_capacity=8)
    plan = pol.plan("m", queue_len=0, now=0.0, max_pp=max_pp,
                    current_workers=1)
    assert plan.mode == "down"
    assert plan.group_sizes == (max_pp,)


@pytest.mark.parametrize("max_pp", [1, 2, 4])
@pytest.mark.parametrize("queue_len", [9, 17, 25, 33, 56])
def test_plan_scale_up_groups_cover_deficit_exactly(max_pp, queue_len):
    """Groups must sum to the deficit (no g=2 overshoot on odd remainders)
    and each group must fit the placement's max_pp."""
    pol = ConsolidationPolicy(SlidingWindowPredictor(60.0),
                              per_worker_capacity=8)
    plan = pol.plan("m", queue_len=queue_len, now=0.0, max_pp=max_pp,
                    current_workers=0)
    assert plan.mode == "up"
    assert sum(plan.group_sizes) == plan.keep_workers
    assert all(1 <= g <= max_pp for g in plan.group_sizes)
