"""Algorithm 2 (contention tracker) property tests."""

import math

from hypothesis import given, settings, strategies as st

from repro.core.placement import ContentionTracker
from repro.core.types import GB, Gbps, ServerSpec


def one_server(bw=2e9):
    return {"s0": ServerSpec("s0", bw, 12e9, 24 * GB)}


def test_empty_server_gives_full_bandwidth():
    tr = ContentionTracker(one_server())
    assert tr.node_bandwidth("s0", 0.0) == 2e9


def test_fair_share_after_admits():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 10e9, deadline=100.0, now=0.0)
    # new worker would share with 1 resident -> B/2
    assert math.isclose(tr.node_bandwidth("s0", 0.0), 1e9)


def test_eq3_rejection():
    tr = ContentionTracker(one_server())
    # resident needs 10 GB by t=6 -> needs >1.6GB/s; B/2=1GB/s violates
    tr.admit("s0", "w1", 10e9, deadline=6.0, now=0.0)
    assert tr.node_bandwidth("s0", 0.0) == 0.0


def test_eq4_settle_and_completion():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 10e9, deadline=100.0, now=0.0)
    # after 5s alone at 2 GB/s it has fetched everything
    assert tr.node_bandwidth("s0", 5.0) == 2e9      # w1 auto-removed
    assert tr.residents("s0") == []


def test_mid_interval_completion_accelerates_survivor():
    """Regression (ISSUE 5): a fetch finishing mid-interval is a
    bandwidth-change event (Eq. 4) — the survivor must be charged the
    full NIC from that instant, not the stale B/n share for the whole
    interval."""
    tr = ContentionTracker(one_server())          # B = 2 GB/s
    tr.admit("s0", "small", 2e9, deadline=100.0, now=0.0)
    tr.admit("s0", "big", 6e9, deadline=100.0, now=0.0)
    # settle at t=3.5: small finished at t=2 (2 GB at B/2); big then ran
    # 1.5 s at the full 2 GB/s -> fetched 2 + 3 = 5 GB, 1 GB pending.
    tr.node_bandwidth("s0", 3.5)
    (big,) = tr.residents("s0")
    assert big.worker_id == "big"
    assert math.isclose(big.pending_bytes, 1e9, rel_tol=1e-9)
    assert math.isclose(tr.finish_time("s0", "small"), 2.0, rel_tol=1e-9)
    # with the undercharging bug big survived past t=4; now it must not
    tr.node_bandwidth("s0", 4.0 + 1e-9)
    assert tr.residents("s0") == []
    assert math.isclose(tr.finish_time("s0", "big"), 4.0, rel_tol=1e-6)


def test_settle_terminates_on_subresolution_residue():
    """A float-noise pending residue just above the done-epsilon, at a
    clock value whose ulp exceeds the residue's drain time, must complete
    immediately instead of spinning the event loop forever."""
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 1e9, deadline=1e9, now=1e6)
    tr.residents("s0")[0].pending_bytes = 2e-6   # > _DONE_EPS, < ulp drain
    tr.node_bandwidth("s0", 1e6 + 10.0)          # must terminate
    assert tr.residents("s0") == []


def test_simultaneous_completions_settle_in_one_event():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 4e9, deadline=100.0, now=0.0)
    tr.admit("s0", "w2", 4e9, deadline=100.0, now=0.0)
    tr.node_bandwidth("s0", 10.0)
    assert tr.residents("s0") == []
    assert math.isclose(tr.finish_time("s0", "w1"), 4.0, rel_tol=1e-9)
    assert math.isclose(tr.finish_time("s0", "w2"), 4.0, rel_tol=1e-9)


def test_explicit_completion():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 10e9, deadline=100.0, now=0.0)
    tr.complete("s0", "w1", 1.0)
    assert tr.residents("s0") == []
    assert tr.node_bandwidth("s0", 1.0) == 2e9


@settings(max_examples=60, deadline=None)
@given(
    sizes=st.lists(st.floats(1e8, 20e9), min_size=1, max_size=6),
    deadline_slack=st.floats(1.0, 500.0),
    dt=st.floats(0.0, 30.0),
)
def test_pending_never_negative_and_monotone(sizes, deadline_slack, dt):
    tr = ContentionTracker(one_server())
    for i, s in enumerate(sizes):
        tr.admit("s0", f"w{i}", s, deadline=deadline_slack + 1000, now=0.0)
    before = {w.worker_id: w.pending_bytes for w in tr.residents("s0")}
    tr.node_bandwidth("s0", dt)   # triggers settle at time dt
    after = {w.worker_id: w.pending_bytes for w in tr.residents("s0")}
    for wid, pb in after.items():
        assert pb >= -1e-6
        assert pb <= before[wid] + 1e-6
    # total fetched bytes cannot exceed capacity B*dt
    fetched = sum(before.values()) - sum(
        after.get(w, 0.0) for w in before)
    assert fetched <= 2e9 * dt + 1e-3


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_admission_is_safe(data):
    """If node_bandwidth returns > 0 and we admit with a deadline computed
    from that bandwidth, all residents can still finish (fluid model)."""
    tr = ContentionTracker(one_server())
    now = 0.0
    admitted = []
    for i in range(data.draw(st.integers(1, 5))):
        size = data.draw(st.floats(1e8, 8e9))
        bw = tr.node_bandwidth("s0", now)
        if bw <= 0:
            break
        deadline = now + size / bw * 1.5
        tr.admit("s0", f"w{i}", size, deadline, now)
        admitted.append((f"w{i}", size, deadline))
        now += data.draw(st.floats(0.0, 0.2))
    # simulate perfect fair-share progress to the last deadline
    if admitted:
        horizon = max(d for _, _, d in admitted)
        tr.node_bandwidth("s0", horizon)
        # any remaining resident must not have passed its deadline by more
        # than numerical noise (the fluid model guarantees feasibility only
        # when Eq.3 held at every admission, which our loop enforced)
        for w in tr.residents("s0"):
            assert w.deadline >= horizon - 1e-6 or w.pending_bytes <= 1e-3
