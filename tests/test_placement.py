"""Algorithm 2 (contention tracker) property tests."""

import math

from hypothesis import given, settings, strategies as st

from repro.core.placement import ContentionTracker
from repro.core.types import GB, Gbps, ServerSpec


def one_server(bw=2e9):
    return {"s0": ServerSpec("s0", bw, 12e9, 24 * GB)}


def test_empty_server_gives_full_bandwidth():
    tr = ContentionTracker(one_server())
    assert tr.node_bandwidth("s0", 0.0) == 2e9


def test_fair_share_after_admits():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 10e9, deadline=100.0, now=0.0)
    # new worker would share with 1 resident -> B/2
    assert math.isclose(tr.node_bandwidth("s0", 0.0), 1e9)


def test_eq3_rejection():
    tr = ContentionTracker(one_server())
    # resident needs 10 GB by t=6 -> needs >1.6GB/s; B/2=1GB/s violates
    tr.admit("s0", "w1", 10e9, deadline=6.0, now=0.0)
    assert tr.node_bandwidth("s0", 0.0) == 0.0


def test_eq4_settle_and_completion():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 10e9, deadline=100.0, now=0.0)
    # after 5s alone at 2 GB/s it has fetched everything
    assert tr.node_bandwidth("s0", 5.0) == 2e9      # w1 auto-removed
    assert tr.residents("s0") == []


def test_explicit_completion():
    tr = ContentionTracker(one_server())
    tr.admit("s0", "w1", 10e9, deadline=100.0, now=0.0)
    tr.complete("s0", "w1", 1.0)
    assert tr.residents("s0") == []
    assert tr.node_bandwidth("s0", 1.0) == 2e9


@settings(max_examples=60, deadline=None)
@given(
    sizes=st.lists(st.floats(1e8, 20e9), min_size=1, max_size=6),
    deadline_slack=st.floats(1.0, 500.0),
    dt=st.floats(0.0, 30.0),
)
def test_pending_never_negative_and_monotone(sizes, deadline_slack, dt):
    tr = ContentionTracker(one_server())
    for i, s in enumerate(sizes):
        tr.admit("s0", f"w{i}", s, deadline=deadline_slack + 1000, now=0.0)
    before = {w.worker_id: w.pending_bytes for w in tr.residents("s0")}
    tr.node_bandwidth("s0", dt)   # triggers settle at time dt
    after = {w.worker_id: w.pending_bytes for w in tr.residents("s0")}
    for wid, pb in after.items():
        assert pb >= -1e-6
        assert pb <= before[wid] + 1e-6
    # total fetched bytes cannot exceed capacity B*dt
    fetched = sum(before.values()) - sum(
        after.get(w, 0.0) for w in before)
    assert fetched <= 2e9 * dt + 1e-3


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_admission_is_safe(data):
    """If node_bandwidth returns > 0 and we admit with a deadline computed
    from that bandwidth, all residents can still finish (fluid model)."""
    tr = ContentionTracker(one_server())
    now = 0.0
    admitted = []
    for i in range(data.draw(st.integers(1, 5))):
        size = data.draw(st.floats(1e8, 8e9))
        bw = tr.node_bandwidth("s0", now)
        if bw <= 0:
            break
        deadline = now + size / bw * 1.5
        tr.admit("s0", f"w{i}", size, deadline, now)
        admitted.append((f"w{i}", size, deadline))
        now += data.draw(st.floats(0.0, 0.2))
    # simulate perfect fair-share progress to the last deadline
    if admitted:
        horizon = max(d for _, _, d in admitted)
        tr.node_bandwidth("s0", horizon)
        # any remaining resident must not have passed its deadline by more
        # than numerical noise (the fluid model guarantees feasibility only
        # when Eq.3 held at every admission, which our loop enforced)
        for w in tr.residents("s0"):
            assert w.deadline >= horizon - 1e-6 or w.pending_bytes <= 1e-3
