"""Scheduler/runner split: scheduling policies, preemption, resume.

Three layers of guarantees:
  * policy units (no model) — FCFS vs priority vs SLO-deadline admission
    ordering, preemption victim selection, and plan-level behaviour of
    ``Scheduler.schedule`` under slot pressure;
  * end-to-end bit-exactness — greedy outputs are identical with and
    without a forced preempt/resume (prefix cache on AND off, and across
    a §6.2 consolidation of the preempted state), and the FCFS policy
    matches the other policies exactly when no priorities/SLOs are set
    (the pre-split engine's behaviour, which the untouched
    test_engine/test_serving_api/test_paged_kv suites pin);
  * overload — under an arrival burst beyond capacity the SLO-deadline
    policy preempts background work and beats FCFS on TTFT-SLO
    attainment.
"""

import jax
import pytest

from conftest import smoke
from repro.core.types import SLO
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine
from repro.serving.kvcache import BlockManager
from repro.serving.scheduler import (FCFSPolicy, GenRequest, PriorityPolicy,
                                     Scheduler, SLOPolicy, make_policy)

PROMPTS = [[5, 7, 9, 11], [3, 1, 4, 1, 5, 9, 2], [42] * 6, [8, 6, 7]]


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Policy units (no model)
# ---------------------------------------------------------------------------


def _req(rid, priority=0, slo=None, submit=0, prompt_len=4, max_new=4):
    r = GenRequest(rid, list(range(prompt_len)),
                   SamplingParams(max_new=max_new, priority=priority,
                                  slo=slo))
    r.metrics.submit_step = submit
    return r


def _running(req, slot, tokens=(1,), last_step=1):
    req.slot = slot
    req.prefill_upto = req.prompt_total
    req.prefilled = req.prompt_total
    req.generated = list(tokens)
    req.metrics.last_token_step = last_step
    return req


def _order(policy, reqs, step=0):
    return [r.rid for r in
            sorted(reqs, key=lambda r: policy.sort_key(r, step))]


def test_fcfs_policy_orders_by_submission_and_never_preempts():
    p = FCFSPolicy()
    reqs = [_req(2, priority=9), _req(0), _req(1, slo=SLO(1.0, 1.0))]
    assert _order(p, reqs) == [0, 1, 2]          # priority/SLO ignored
    victims = [_running(_req(5), 0), _running(_req(6), 1)]
    assert p.victim(victims, _req(7, priority=9), step=3) is None


def test_priority_policy_order_and_victim():
    p = PriorityPolicy()
    reqs = [_req(0, priority=0), _req(1, priority=2), _req(2, priority=2)]
    assert _order(p, reqs) == [1, 2, 0]          # high first, FCFS within
    running = [_running(_req(3, priority=1), 0),
               _running(_req(4, priority=0), 1),
               _running(_req(5, priority=0), 2)]
    # victim: lowest priority, newest within the level
    assert p.victim(running, _req(6, priority=2), step=3).rid == 5
    # never preempts an equal-or-higher priority resident
    assert p.victim(running[:1], _req(7, priority=1), step=3) is None


def test_slo_policy_edf_order_and_victim():
    p = SLOPolicy()
    tight = _req(2, slo=SLO(ttft=3.0, tpot=5.0), submit=0)
    loose = _req(0, slo=SLO(ttft=50.0, tpot=5.0), submit=0)
    none = _req(1)                               # background: deadline inf
    assert _order(p, [none, loose, tight]) == [2, 0, 1]
    # a streaming request's deadline tracks its last token + tpot budget
    streaming = _running(_req(3, slo=SLO(ttft=3.0, tpot=2.0)), 0,
                         last_step=10)
    assert p.deadline(streaming) == 12.0
    bg = _running(_req(4), 1, last_step=10)      # no SLO: inf deadline
    # the latest-deadline resident goes first; never for a later incoming
    assert p.victim([streaming, bg], tight, step=11).rid == 4
    assert p.victim([streaming], _req(5, slo=SLO(ttft=99.0, tpot=99.0),
                                      submit=0), step=11) is None


def test_make_policy_lookup_and_passthrough():
    assert isinstance(make_policy("fcfs"), FCFSPolicy)
    assert isinstance(make_policy("priority"), PriorityPolicy)
    assert isinstance(make_policy("slo"), SLOPolicy)
    inst = SLOPolicy()
    assert make_policy(inst) is inst
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("edf")


def test_scheduler_plans_preemption_under_slot_pressure():
    """Plan-level check, no model: with both slots held by background
    work, a higher-priority submission is admitted by preempting the
    newest low-priority resident; the victim's blocks are released and
    it moves to the preempted pool for re-admission."""
    bm = BlockManager(n_blocks=16, block_size=4, bytes_per_token=2)
    sched = Scheduler(bm, max_batch=2, policy="priority")
    bg = []
    for rid in (0, 1):
        r = _req(rid, priority=0)
        bm.allocate(rid, r.prompt_total)
        bg.append(_running(r, rid))
    sched.slots = [bg[0], bg[1]]
    hi = _req(2, priority=5)
    sched.submit(hi)
    sched.begin_step(2, float("inf"))
    plan = sched.schedule()
    assert [r.rid for r in plan.admitted] == [2]
    assert [(r.rid, s) for r, s in plan.preempted] == [(1, 1)]
    assert plan.prefills[0].req is hi and plan.prefills[0].n == 4
    assert hi.slot == 1 and bg[1].slot is None
    assert bg[1] in sched.preempted and bg[1].metrics.preemptions == 1
    assert bg[1].rid not in bm.tables            # blocks released
    assert plan.decodes == (bg[0],)              # victim left the batch
    # FCFS under the same pressure defers instead
    sched2 = Scheduler(BlockManager(16, 4, 2), max_batch=1, policy="fcfs")
    res = _running(_req(0), 0)
    sched2.block_mgr.allocate(0, res.prompt_total)
    sched2.slots = [res]
    sched2.submit(_req(1, priority=5))
    sched2.begin_step(2, float("inf"))
    plan2 = sched2.schedule()
    assert plan2.idle and not plan2.admitted and not plan2.preempted


# ---------------------------------------------------------------------------
# End-to-end (model): bit-exactness and policy equivalence
# ---------------------------------------------------------------------------


def _stream(cfg, params, policy="fcfs", **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    eng = Engine(cfg, [params], policy=policy, **kw)
    reqs = [eng.submit(p, SamplingParams(max_new=8)) for p in PROMPTS]
    eng.run()
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs], eng


def test_policies_identical_without_knobs(granite):
    """With no priorities/SLOs set every policy degenerates to FCFS and
    all greedy streams are bit-exact — the pre-split engine's behaviour
    (pinned by the untouched engine/serving suites) in both layouts."""
    cfg, params = granite
    want, _ = _stream(cfg, params, policy="fcfs", paged=False)
    for policy in ("fcfs", "priority", "slo"):
        got, eng = _stream(cfg, params, policy=policy, paged=True)
        assert got == want
        assert eng.scheduler.n_preemptions == 0


@pytest.mark.parametrize("prefix_cache", [False, True])
def test_forced_preempt_resume_bit_exact(granite, prefix_cache):
    """A preempted-and-resumed greedy request reproduces its
    uninterrupted token stream exactly. With the prefix cache on, the
    resume re-prefills only the uncached tail (cached_tokens covers the
    committed full blocks of prompt + emitted tokens)."""
    cfg, params = granite
    ref = Engine(cfg, [params], max_batch=2, max_seq=64, block_size=8,
                 paged=True, prefix_cache=prefix_cache)
    want = [ref.submit(list(range(3, 21)), SamplingParams(max_new=10)),
            ref.submit(PROMPTS[1], SamplingParams(max_new=10))]
    ref.run()

    eng = Engine(cfg, [params], max_batch=2, max_seq=64, block_size=8,
                 paged=True, prefix_cache=prefix_cache)
    victim = eng.submit(list(range(3, 21)), SamplingParams(max_new=10))
    other = eng.submit(PROMPTS[1], SamplingParams(max_new=10))
    for _ in range(4):
        eng.step()
    assert not victim.done and len(victim.generated) >= 3
    eng.preempt(victim)
    assert victim.slot is None and victim.metrics.preemptions == 1
    out = eng.step()                      # other decodes; victim resumes
    assert any(ev.rid == other.rid for ev in out.events)
    eng.run()
    assert victim.generated == want[0].generated
    assert other.generated == want[1].generated
    if prefix_cache:
        # resume reused the committed prefix blocks: prompt(18 rows) +
        # emitted tokens had >= 2 full blocks of 8 committed
        assert victim.metrics.cached_tokens >= 16
    else:
        assert victim.metrics.cached_tokens == 0
    bm = eng.block_mgr
    assert bm.free_blocks == bm.n_blocks
    assert bm.preempt_releases == 1


def test_priority_preemption_under_pressure_bit_exact(granite):
    """With a single slot, a high-priority arrival evicts the running
    low-priority request; both streams still match their uninterrupted
    references after the victim resumes."""
    cfg, params = granite
    def solo(prompt, max_new):
        e = Engine(cfg, [params], max_batch=1, max_seq=64, block_size=8,
                   paged=True, prefix_cache=True)
        r = e.submit(prompt, SamplingParams(max_new=max_new))
        e.run()
        return r.generated

    eng = Engine(cfg, [params], max_batch=1, max_seq=64, block_size=8,
                 paged=True, prefix_cache=True, policy="priority")
    bg = eng.submit(list(range(3, 19)),
                    SamplingParams(max_new=12, priority=0))
    for _ in range(3):
        eng.step()
    hi = eng.submit(PROMPTS[0], SamplingParams(max_new=4, priority=3))
    out = eng.step()
    assert out.preempted == (bg.rid,)
    assert hi.slot is not None            # admitted into the vacated slot
    eng.run()
    assert hi.done and bg.done
    assert hi.generated == solo(PROMPTS[0], 4)
    assert bg.generated == solo(list(range(3, 19)), 12)
    assert bg.metrics.preemptions == 1


def test_preempted_request_survives_consolidation(granite):
    """§6.2 scale-down with a request sitting in the preempted pool: the
    policy and the pool carry over to the consolidated engine, the
    resume re-prefills from scratch (cold caches are dropped at
    migration), and the stream stays bit-exact."""
    cfg, params = granite
    m = build_model(cfg)
    ref = Engine(cfg, [params], max_batch=2, max_seq=64, block_size=8,
                 paged=True, prefix_cache=True)
    want = [ref.submit(list(range(3, 19)), SamplingParams(max_new=8)),
            ref.submit(PROMPTS[1], SamplingParams(max_new=8))]
    ref.run()

    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(Engine(cfg, sp, max_batch=2, max_seq=64,
                                block_size=8, paged=True, prefix_cache=True,
                                policy="slo"))
    a = ep.submit(list(range(3, 19)), SamplingParams(max_new=8))
    b = ep.submit(PROMPTS[1], SamplingParams(max_new=8))
    for _ in range(3):
        ep.step()
    ep.engine.preempt(a)
    ep.consolidate(params)
    assert ep.policy.name == "slo"        # policy survives the swap
    assert a in ep.engine.scheduler.preempted
    ep.run()
    assert a.generated == want[0].generated
    assert b.generated == want[1].generated
    assert a.metrics.preemptions == 1


def test_slo_policy_beats_fcfs_on_overload(granite):
    """Arrival burst beyond capacity with mixed priorities/SLOs: the
    SLO-deadline policy preempts loose background work to serve
    tight-TTFT requests and attains strictly more TTFT SLOs than FCFS."""
    cfg, params = granite

    def attainment(policy):
        eng = Engine(cfg, [params], max_batch=2, max_seq=96, block_size=8,
                     paged=True, prefix_cache=True, policy=policy)
        background = [
            eng.submit([10 + i] * 16,
                       SamplingParams(max_new=16, priority=0,
                                      slo=SLO(ttft=200.0, tpot=60.0)))
            for i in range(2)]
        for _ in range(3):
            eng.step()
        interactive = [
            eng.submit([50 + i] * 4,
                       SamplingParams(max_new=4, priority=2,
                                      slo=SLO(ttft=6.0, tpot=30.0)))
            for i in range(3)]
        eng.run()
        reqs = background + interactive
        assert all(r.done for r in reqs)
        hits = sum(r.metrics.ttft_steps <= r.params.slo.ttft for r in reqs)
        return hits / len(reqs), eng.scheduler.n_preemptions

    fcfs, fcfs_preempts = attainment("fcfs")
    slo, slo_preempts = attainment("slo")
    assert fcfs_preempts == 0             # FCFS never preempts
    assert slo_preempts > 0               # EDF sheds background load
    assert slo > fcfs
