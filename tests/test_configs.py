"""Assigned architecture configs carry the exact published hyperparameters."""

import pytest

from repro.configs import SHAPES, applicable_shapes, get_config, list_configs

EXPECTED = {
    "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12800, vocab=49155),
    "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48,
                          n_kv_heads=8, d_ff=16384, vocab=92544),
    "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36,
                          n_kv_heads=4, d_ff=18432, vocab=49152),
    "qwen1.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                        n_kv_heads=40, d_ff=27392, vocab=152064,
                        qkv_bias=True),
    "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                            n_kv_heads=16, d_ff=1408, vocab=151936,
                            n_experts=60, top_k=4, n_shared_experts=4),
    "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
                        d_ff=32768, vocab=131072, n_experts=8, top_k=2),
    "llava-next-34b": dict(n_layers=60, d_model=7168, n_heads=56,
                           n_kv_heads=8, d_ff=20480, vocab=64000),
    "whisper-small": dict(n_layers=12, encoder_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865),
    "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab=65536,
                           n_experts=16, top_k=2),
    "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab=65536),
}


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_config(name):
    cfg = get_config(name)
    for k, v in EXPECTED[name].items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_all_registered():
    names = set(list_configs())
    assert set(EXPECTED) <= names
    assert {"llama2-7b", "llama2-13b", "opt-6.7b"} <= names


def test_shape_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


def test_long_context_applicability():
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    for name in EXPECTED:
        cfg = get_config(name)
        shapes = {s.name for s in applicable_shapes(cfg)}
        if name in ("jamba-v0.1-52b", "rwkv6-1.6b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_cell_count():
    """10 archs x (3 shapes + long for ssm/hybrid) = 32 runnable cells of
    the assigned 40 (8 long_500k skips recorded in DESIGN.md)."""
    total = sum(len(applicable_shapes(get_config(n))) for n in EXPECTED)
    assert total == 32


def test_param_counts_plausible():
    # loose bands: configs should be in the advertised size class
    assert 7e9 < get_config("granite-3-8b").param_count() < 10e9
    assert 17e9 < get_config("internlm2-20b").param_count() < 23e9
    assert 250e9 < get_config("grok-1-314b").param_count() < 380e9
    assert 45e9 < get_config("jamba-v0.1-52b").param_count() < 60e9
    assert 1.2e9 < get_config("rwkv6-1.6b").param_count() < 2.2e9
    moe = get_config("qwen2-moe-a2.7b")
    assert moe.active_param_count() < 0.45 * moe.param_count()
