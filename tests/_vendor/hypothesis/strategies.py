"""Strategy combinators for the vendored hypothesis shim (see __init__)."""

from __future__ import annotations

import random
from typing import Sequence


class SearchStrategy:
    def example(self, rnd: random.Random):
        raise NotImplementedError


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rnd):
        # hit the endpoints occasionally — they are the classic bug nests
        r = rnd.random()
        if r < 0.05:
            return self.min_value
        if r < 0.1:
            return self.max_value
        return rnd.uniform(self.min_value, self.max_value)


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rnd):
        return rnd.randint(self.min_value, self.max_value)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size: int = 0,
                 max_size: int = 10):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        return [self.elements.example(rnd) for _ in range(n)]


class _SampledFrom(SearchStrategy):
    def __init__(self, options: Sequence):
        self.options = list(options)

    def example(self, rnd):
        return rnd.choice(self.options)


class DataObject:
    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.example(self._rnd)


class _Data(SearchStrategy):
    def example(self, rnd):
        return DataObject(rnd)


def floats(min_value, max_value):
    return _Floats(min_value, max_value)


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def lists(elements, min_size=0, max_size=10):
    return _Lists(elements, min_size, max_size)


def sampled_from(options):
    return _SampledFrom(options)


def data():
    return _Data()
