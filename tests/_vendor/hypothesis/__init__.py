"""Minimal deterministic stand-in for the ``hypothesis`` API this repo uses.

Activated by tests/conftest.py ONLY when the real hypothesis isn't
installed (CI installs it from pyproject; hermetic images may not have
it). It runs each ``@given`` test ``max_examples`` times with a seeded
PRNG — plain randomized testing, no shrinking or failure database — so
the property tests still exercise their invariants instead of being
skipped.
"""

from __future__ import annotations

import random

from . import strategies

__version__ = "0.0-repro-shim"
__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 100
_SEED = 0xC0FFEE


class _Settings:
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._hyp_settings = self
        return fn


settings = _Settings


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        def runner():
            cfg = getattr(runner, "_hyp_settings", None) \
                or getattr(fn, "_hyp_settings", None) or _Settings()
            rnd = random.Random(_SEED)
            for _ in range(cfg.max_examples):
                args = [s.example(rnd) for s in arg_strategies]
                kwargs = {k: s.example(rnd)
                          for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # plain () signature so pytest doesn't mistake the strategy
        # parameters for fixtures (no functools.wraps / __wrapped__)
        runner.__name__ = fn.__name__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        # pytest plugins (e.g. anyio) look for .hypothesis.inner_test
        runner.hypothesis = type("_Hyp", (), {"inner_test": fn})()
        return runner

    return decorate
