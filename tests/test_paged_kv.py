"""Paged KV-cache serving path.

Three layers of guarantees:
  * kernel — the Pallas paged decode kernel (interpret mode) and the
    blocked jnp reference agree with the contiguous-gather oracle for
    ragged lengths, both dtypes, both page sizes;
  * engine — a paged engine produces the same greedy tokens as the
    slot-contiguous engine on identical prompts;
  * consolidation — §6.2 migration at block granularity: in-flight
    requests continue bit-exactly after ``consolidated()`` and the bytes
    gathered equal the BlockManager's ``migration_bytes`` quote.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.kernels import ops, ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine

PROMPTS = [[5, 7, 9, 11], [3, 1, 4, 1, 5, 9, 2], [42] * 6, [8, 6, 7]]


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 3e-2


def _paged_case(rng, b, hq, hkv, hd, page_size, nb, dtype):
    n_pages = b * nb + 1
    q = jnp.asarray(rng.standard_normal((b, 1, hq, hd)), dtype)
    kp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, hd)),
                     dtype)
    vp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, hd)),
                     dtype)
    # non-trivial page assignment: shuffled, page 0 unused by any table
    perm = rng.permutation(n_pages - 1) + 1
    bt = jnp.asarray(perm[: b * nb].reshape(b, nb), jnp.int32)
    lens = jnp.asarray(rng.integers(1, nb * page_size + 1, b), jnp.int32)
    return q, kp, vp, bt, lens


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,hd,page_size,nb", [
    (2, 8, 2, 64, 16, 5),
    (3, 4, 4, 32, 64, 3),
    (2, 6, 1, 64, 16, 4),
    (1, 16, 8, 128, 64, 2),
])
def test_paged_decode_kernel_matches_oracle(b, hq, hkv, hd, page_size, nb,
                                            dtype):
    rng = np.random.default_rng(11)
    q, kp, vp, bt, lens = _paged_case(rng, b, hq, hkv, hd, page_size, nb,
                                      dtype)
    # oracle: gather the table into a contiguous cache, masked attention
    kc = kp[bt].reshape(b, nb * page_size, hkv, hd)
    vc = vp[bt].reshape(b, nb * page_size, hkv, hd)
    want = ref.decode_attention_reference(q, kc, vc, lens)

    got_kernel = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    got_ref = ref.paged_decode_attention_reference(q, kp, vp, bt, lens)
    for got in (got_kernel, got_ref):
        err = jnp.max(jnp.abs(got.astype(jnp.float32)
                              - want.astype(jnp.float32)))
        assert float(err) < _tol(dtype), err


def test_paged_ref_handles_zero_length_rows():
    """Idle batch rows (kv_len == 0, table all null) must not produce NaNs."""
    rng = np.random.default_rng(3)
    q, kp, vp, bt, _ = _paged_case(rng, 2, 4, 2, 32, 16, 3, jnp.float32)
    lens = jnp.asarray([5, 0], jnp.int32)
    out = ref.paged_decode_attention_reference(q, kp, vp, bt, lens)
    assert bool(jnp.all(jnp.isfinite(out)))
    out_k = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out_k)))


def test_ops_dispatch_paged_mode():
    prev = ops.decode_mode()
    try:
        ops.set_decode_mode("paged")
        assert ops.decode_mode() == "paged"
    finally:
        ops.set_decode_mode(prev)
    with pytest.raises(AssertionError):
        ops.set_decode_mode("bogus")


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_engine_paged_matches_contiguous(granite):
    cfg, params = granite
    outs = {}
    for paged in (False, True):
        ep = ServingEndpoint(Engine(cfg, [params], max_batch=3, max_seq=64,
                                    paged=paged))
        reqs = [ep.submit(p, SamplingParams(max_new=8)) for p in PROMPTS]
        ep.run()
        assert all(r.done for r in reqs)
        outs[paged] = [r.generated for r in reqs]
        bm = ep.engine.block_mgr
        assert bm.free_blocks == bm.n_blocks
    assert outs[True] == outs[False]


@pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b"])
def test_paged_consolidation_block_exact(arch, rng):
    """In-flight requests continue bit-exactly across a paged scale-down,
    and the gather moves exactly the bytes the BlockManager quotes."""
    cfg = smoke(arch)
    m = build_model(cfg)
    params = m.init(rng)

    ref_ep = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=48,
                                    paged=True))
    ref_reqs = [ref_ep.submit(p, SamplingParams(max_new=8))
                for p in PROMPTS[:2]]
    ref_ep.run()

    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(Engine(cfg, sp, max_batch=2, max_seq=48,
                                paged=True))
    reqs = [ep.submit(p, SamplingParams(max_new=8)) for p in PROMPTS[:2]]
    for _ in range(3):
        ep.step()
    live_rids = [r.rid for r in ep.active()]
    n_remote = ep.engine.n_attn_layers(migrated_only=True)
    quoted = ep.engine.block_mgr.migration_bytes(live_rids, n_remote)
    ep.consolidate(params)
    assert ep.last_migration_bytes == quoted
    # only a degenerate split (all periods on the surviving stage, e.g.
    # jamba-smoke's single period) legitimately ships zero KV bytes
    assert (quoted > 0) == (n_remote > 0)
    ep.run()
    assert [r.generated for r in reqs] == [r.generated for r in ref_reqs]


def test_admission_defers_instead_of_raising(granite):
    """When the pool can't hold a request, it waits in the queue — no
    MemoryError mid-flight — and is served once blocks free up."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64, paged=True)
    bs = eng.block_mgr.block_size
    # a co-tenant hogs the whole pool
    eng.block_mgr.allocate(-1, eng.block_mgr.n_blocks * bs)
    r = eng.submit(PROMPTS[0], SamplingParams(max_new=4))
    eng.step()
    assert r.slot is None and not r.done and len(eng.queue) == 1
    eng.block_mgr.free(-1)
    eng.run()
    assert r.done and len(r.generated) == 4


def test_submit_rejects_requests_larger_than_max_seq(granite):
    """prompt + max_new beyond max_seq can't be cached in either layout —
    reject at submit instead of overflowing block tables mid-flight."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64, paged=True)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit([1] * 60, SamplingParams(max_new=60))
    # boundary case fits exactly
    r = eng.submit([1] * 60, SamplingParams(max_new=4))
    eng.run()
    assert r.done and len(r.generated) == 4


def test_engine_paged_default_follows_decode_mode(granite):
    cfg, params = granite
    prev = ops.decode_mode()
    try:
        ops.set_decode_mode("paged")
        eng = Engine(cfg, [params], max_batch=2, max_seq=64)
        assert eng.paged
    finally:
        ops.set_decode_mode(prev)
