"""Paged KV-cache serving path.

Four layers of guarantees:
  * kernel — the Pallas paged decode kernel (interpret mode) and the
    blocked jnp reference agree with the contiguous-gather oracle for
    ragged lengths, both dtypes, both page sizes;
  * engine — a paged engine produces the same greedy tokens as the
    slot-contiguous engine on identical prompts;
  * prefix cache / chunked prefill — the content-addressed pool shares
    prefix blocks (hit / miss / copy-on-write / LRU eviction), suffix-only
    and chunked prefill stay bit-exact with monolithic uncached prefill,
    and mixed steps keep decodes flowing while a long prompt prefills;
  * consolidation — §6.2 migration at block granularity: in-flight
    requests (including half-prefilled ones) continue bit-exactly after
    ``consolidated()`` and the bytes gathered equal the BlockManager's
    dedup-aware ``migration_bytes`` quote (each shared block once).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.kernels import ops, ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.models import build_model
from repro.serving.api import SamplingParams
from repro.serving.endpoint import ServingEndpoint
from repro.serving.engine import Engine
from repro.serving.kvcache import BlockManager

PROMPTS = [[5, 7, 9, 11], [3, 1, 4, 1, 5, 9, 2], [42] * 6, [8, 6, 7]]


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 3e-2


def _paged_case(rng, b, hq, hkv, hd, page_size, nb, dtype):
    n_pages = b * nb + 1
    q = jnp.asarray(rng.standard_normal((b, 1, hq, hd)), dtype)
    kp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, hd)),
                     dtype)
    vp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, hd)),
                     dtype)
    # non-trivial page assignment: shuffled, page 0 unused by any table
    perm = rng.permutation(n_pages - 1) + 1
    bt = jnp.asarray(perm[: b * nb].reshape(b, nb), jnp.int32)
    lens = jnp.asarray(rng.integers(1, nb * page_size + 1, b), jnp.int32)
    return q, kp, vp, bt, lens


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,hd,page_size,nb", [
    (2, 8, 2, 64, 16, 5),
    (3, 4, 4, 32, 64, 3),
    (2, 6, 1, 64, 16, 4),
    (1, 16, 8, 128, 64, 2),
])
def test_paged_decode_kernel_matches_oracle(b, hq, hkv, hd, page_size, nb,
                                            dtype):
    rng = np.random.default_rng(11)
    q, kp, vp, bt, lens = _paged_case(rng, b, hq, hkv, hd, page_size, nb,
                                      dtype)
    # oracle: gather the table into a contiguous cache, masked attention
    kc = kp[bt].reshape(b, nb * page_size, hkv, hd)
    vc = vp[bt].reshape(b, nb * page_size, hkv, hd)
    want = ref.decode_attention_reference(q, kc, vc, lens)

    got_kernel = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    got_ref = ref.paged_decode_attention_reference(q, kp, vp, bt, lens)
    for got in (got_kernel, got_ref):
        err = jnp.max(jnp.abs(got.astype(jnp.float32)
                              - want.astype(jnp.float32)))
        assert float(err) < _tol(dtype), err


def test_paged_ref_handles_zero_length_rows():
    """Idle batch rows (kv_len == 0, table all null) must not produce NaNs."""
    rng = np.random.default_rng(3)
    q, kp, vp, bt, _ = _paged_case(rng, 2, 4, 2, 32, 16, 3, jnp.float32)
    lens = jnp.asarray([5, 0], jnp.int32)
    out = ref.paged_decode_attention_reference(q, kp, vp, bt, lens)
    assert bool(jnp.all(jnp.isfinite(out)))
    out_k = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out_k)))


def test_ops_dispatch_paged_mode():
    prev = ops.decode_mode()
    try:
        ops.set_decode_mode("paged")
        assert ops.decode_mode() == "paged"
    finally:
        ops.set_decode_mode(prev)
    with pytest.raises(AssertionError):
        ops.set_decode_mode("bogus")


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-8b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_engine_paged_matches_contiguous(granite):
    cfg, params = granite
    outs = {}
    for paged in (False, True):
        ep = ServingEndpoint(Engine(cfg, [params], max_batch=3, max_seq=64,
                                    paged=paged))
        reqs = [ep.submit(p, SamplingParams(max_new=8)) for p in PROMPTS]
        ep.run()
        assert all(r.done for r in reqs)
        outs[paged] = [r.generated for r in reqs]
        bm = ep.engine.block_mgr
        assert bm.free_blocks == bm.n_blocks
    assert outs[True] == outs[False]


@pytest.mark.parametrize("arch", ["granite-3-8b", "jamba-v0.1-52b"])
def test_paged_consolidation_block_exact(arch, rng):
    """In-flight requests continue bit-exactly across a paged scale-down,
    and the gather moves exactly the bytes the BlockManager quotes."""
    cfg = smoke(arch)
    m = build_model(cfg)
    params = m.init(rng)

    ref_ep = ServingEndpoint(Engine(cfg, [params], max_batch=2, max_seq=48,
                                    paged=True))
    ref_reqs = [ref_ep.submit(p, SamplingParams(max_new=8))
                for p in PROMPTS[:2]]
    ref_ep.run()

    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(Engine(cfg, sp, max_batch=2, max_seq=48,
                                paged=True))
    reqs = [ep.submit(p, SamplingParams(max_new=8)) for p in PROMPTS[:2]]
    for _ in range(3):
        ep.step()
    live_rids = [r.rid for r in ep.active()]
    n_remote = ep.engine.n_attn_layers(migrated_only=True)
    quoted = ep.engine.block_mgr.migration_bytes(live_rids, n_remote)
    ep.consolidate(params)
    assert ep.last_migration_bytes == quoted
    # only a degenerate split (all periods on the surviving stage, e.g.
    # jamba-smoke's single period) legitimately ships zero KV bytes
    assert (quoted > 0) == (n_remote > 0)
    ep.run()
    assert [r.generated for r in reqs] == [r.generated for r in ref_reqs]


def test_admission_defers_instead_of_raising(granite):
    """When the pool can't hold a request, it waits in the queue — no
    MemoryError mid-flight — and is served once blocks free up."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64, paged=True)
    bs = eng.block_mgr.block_size
    # a co-tenant hogs the whole pool
    eng.block_mgr.allocate(-1, eng.block_mgr.n_blocks * bs)
    r = eng.submit(PROMPTS[0], SamplingParams(max_new=4))
    eng.step()
    assert r.slot is None and not r.done and len(eng.queue) == 1
    eng.block_mgr.free(-1)
    eng.run()
    assert r.done and len(r.generated) == 4


def test_submit_rejects_requests_larger_than_max_seq(granite):
    """prompt + max_new beyond max_seq can't be cached in either layout —
    reject at submit instead of overflowing block tables mid-flight."""
    cfg, params = granite
    eng = Engine(cfg, [params], max_batch=2, max_seq=64, paged=True)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit([1] * 60, SamplingParams(max_new=60))
    # boundary case fits exactly
    r = eng.submit([1] * 60, SamplingParams(max_new=4))
    eng.run()
    assert r.done and len(r.generated) == 4


def test_engine_paged_default_follows_decode_mode(granite):
    cfg, params = granite
    prev = ops.decode_mode()
    try:
        ops.set_decode_mode("paged")
        eng = Engine(cfg, [params], max_batch=2, max_seq=64)
        assert eng.paged
    finally:
        ops.set_decode_mode(prev)


# ---------------------------------------------------------------------------
# BlockManager: content-addressed pool (unit level, no model)
# ---------------------------------------------------------------------------


def test_block_manager_prefix_hit_miss_and_refcounts():
    bm = BlockManager(n_blocks=8, block_size=4, bytes_per_token=2,
                      prefix_cache=True)
    toks = list(range(10))                       # 2 full blocks + partial
    t0 = bm.allocate(0, 10, tokens=toks)
    assert t0.cached_tokens == 0 and len(t0.blocks) == 3
    bm.commit(0, 10)                             # registers blocks 0 and 1
    t1 = bm.allocate(1, 10, tokens=toks)         # hit: shares both full blocks
    assert t1.cached_tokens == 8
    assert t1.blocks[:2] == t0.blocks[:2]        # shared
    assert t1.blocks[2] != t0.blocks[2]          # private partial block
    assert bm.refcount(t0.blocks[0]) == 2
    # dedup-aware gathering: 4 unique blocks back 2 requests (6 table rows)
    assert len(bm.blocks_of([0, 1])) == 4
    assert bm.migration_bytes([0, 1], n_layers=1) == 4 * 4 * 2
    miss = bm.allocate(2, 10, tokens=[99] * 10)  # different chain: miss
    assert miss.cached_tokens == 0
    bm.free(0)
    assert bm.refcount(t0.blocks[0]) == 1        # still referenced by req 1
    bm.free(1)
    bm.free(2)
    assert bm.free_blocks == 8                   # cached blocks stay claimable
    assert bm.n_cached > 0                       # ...but keep their content
    t3 = bm.allocate(3, 10, tokens=toks)         # prefix survives free()
    assert t3.cached_tokens == 8


def test_block_manager_cow_on_fully_cached_prompt():
    """A full-prompt hit recomputes the last token into a private
    copy-on-write block — the shared page is never written through."""
    bm = BlockManager(n_blocks=8, block_size=4, bytes_per_token=2,
                      prefix_cache=True)
    toks = list(range(8))                        # exactly 2 blocks
    t0 = bm.allocate(0, 8, tokens=toks)
    bm.commit(0, 8)
    t1 = bm.allocate(1, 8, tokens=toks)
    assert t1.cached_tokens == 7                 # always >= 1 token computed
    copies = bm.drain_copies()
    assert copies == [(t0.blocks[1], t1.blocks[1])]
    assert t1.blocks[0] == t0.blocks[0]          # first block shared
    assert t1.blocks[1] != t0.blocks[1]          # last block private
    assert bm.refcount(t0.blocks[1]) == 1        # COW pin released at drain
    bm.free(0)
    bm.free(1)
    assert bm.free_blocks == 8


def test_block_manager_lru_eviction_prunes_index():
    """Eviction takes refcount-zero cached blocks LRU-first (and within a
    freed request tail-before-head, so shorter prefixes outlive longer
    ones) and drops their index entries."""
    bm = BlockManager(n_blocks=4, block_size=4, bytes_per_token=2,
                      prefix_cache=True)
    bm.allocate(0, 8, tokens=[1] * 8)
    bm.commit(0, 8)
    bm.free(0)                                   # chain A cached (LRU-old)
    bm.allocate(1, 8, tokens=[2] * 8)
    bm.commit(1, 8)
    bm.free(1)                                   # chain B cached (recent)
    assert bm.free_blocks == 4 and bm.n_cached == 4
    bm.allocate(2, 8, tokens=[3] * 8)            # miss: evicts chain A
    assert bm.evictions == 2
    bm.free(2)
    # chain A's index entries are gone: full miss; chain B intact
    assert bm.allocate(3, 8, tokens=[1] * 8).cached_tokens == 0
    bm.free(3)
    t = bm.allocate(4, 8, tokens=[2] * 8)        # full-prompt COW hit
    assert t.cached_tokens == 7
    bm.drain_copies()


def test_block_manager_commit_gates_registration():
    """Blocks enter the index only once their KV is committed — a
    half-prefilled request never exposes unwritten pages for sharing."""
    bm = BlockManager(n_blocks=8, block_size=4, bytes_per_token=2,
                      prefix_cache=True)
    toks = list(range(12))
    bm.allocate(0, 12, tokens=toks)              # nothing committed yet
    assert bm.allocate(1, 12, tokens=toks).cached_tokens == 0
    bm.free(1)
    bm.commit(0, 5)                              # only block 0 is material
    assert bm.allocate(2, 12, tokens=toks).cached_tokens == 4


def test_block_manager_legacy_token_free_path():
    """Callers that never pass token ids get plain ref-counted blocks:
    no hashing, no caching on free."""
    bm = BlockManager(n_blocks=10, block_size=4, bytes_per_token=8,
                      prefix_cache=True)
    bm.allocate(0, 9)
    bm.commit(0, 9)                              # no-op without tokens
    bm.free(0)
    assert bm.n_cached == 0 and bm.free_blocks == 10
    assert bm.blocks_needed(0) == 0
    assert bm.blocks_needed(1) == bm.blocks_needed(4) == 1
    assert bm.blocks_needed(5) == 2


# ---------------------------------------------------------------------------
# Engine: prefix cache + chunked prefill (bit-exactness and scheduling)
# ---------------------------------------------------------------------------

SHARED_PREFIX = list(range(1, 17))               # 2 blocks at block_size=8
TAILS = [[101, 103], [7, 9, 11]]


def _prefix_engine(cfg, stage_params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("block_size", 8)
    kw.setdefault("paged", True)
    return Engine(cfg, stage_params, **kw)


def _run_pair(cfg, params, **kw):
    eng = _prefix_engine(cfg, [params], **kw)
    reqs = [eng.submit(SHARED_PREFIX + t, SamplingParams(max_new=6))
            for t in TAILS]
    eng.run()
    return reqs, eng


def test_prefix_cache_suffix_only_prefill_bit_exact(granite):
    """The second request of a shared-prefix pair prefills only its
    suffix (cached_tokens == shared prefix) and its greedy stream is
    bit-exact with the uncached paged AND contiguous engines."""
    cfg, params = granite
    ref_c, _ = _run_pair(cfg, params, paged=False)
    ref_p, _ = _run_pair(cfg, params)
    hit, eng = _run_pair(cfg, params, prefix_cache=True)
    for a, b, c in zip(ref_c, ref_p, hit):
        assert a.generated == b.generated == c.generated
    assert hit[0].metrics.cached_tokens == 0     # first writer: cold
    assert hit[1].metrics.cached_tokens == len(SHARED_PREFIX)
    bm = eng.block_mgr
    assert bm.cache_hit_tokens >= len(SHARED_PREFIX)
    assert bm.free_blocks == bm.n_blocks         # all reclaimed (or cached)


def test_prefix_cache_cow_rehit_bit_exact(granite):
    """Submitting an identical prompt after the first finished hits the
    whole prompt (minus the resampled last token) through COW."""
    cfg, params = granite
    eng = _prefix_engine(cfg, [params], prefix_cache=True)
    r1 = eng.submit(SHARED_PREFIX, SamplingParams(max_new=4))
    eng.run()
    r2 = eng.submit(SHARED_PREFIX, SamplingParams(max_new=4))
    eng.run()
    assert r2.generated == r1.generated
    assert r2.metrics.cached_tokens == len(SHARED_PREFIX) - 1


def test_chunked_prefill_bit_exact_and_mixed_steps(granite):
    """A long prompt prefilling in chunks (a) produces the same greedy
    stream as monolithic prefill, and (b) shares its steps with the
    in-flight decodes (mixed StepOutputs) instead of stalling them."""
    cfg, params = granite
    long_prompt = list(range(3, 27))             # 24 tokens
    ref = _prefix_engine(cfg, [params])
    want_long = ref.submit(long_prompt, SamplingParams(max_new=4))
    want_short = ref.submit([9, 8, 7], SamplingParams(max_new=10))
    ref.run()

    eng = _prefix_engine(cfg, [params], prefill_chunk=7)
    short = eng.submit([9, 8, 7], SamplingParams(max_new=10))
    eng.step()                                   # short is decoding...
    long = eng.submit(long_prompt, SamplingParams(max_new=4))
    mixed = 0
    while not long.done or not short.done:
        out = eng.step()
        assert out.prefill_tokens <= 7           # budget respected
        if out.prefill_tokens and out.events:
            mixed += 1
        if not long.prefill_done:
            # decode-heavy traffic keeps flowing during the long prefill
            assert any(ev.rid == short.rid for ev in out.events)
    assert mixed >= 3                            # ceil(24 / 7) chunk steps
    assert long.generated == want_long.generated
    assert short.generated == want_short.generated
    assert long.metrics.queue_steps >= 3         # chunking shows up in TTFT


def test_eviction_frees_cached_blocks_before_deferring(granite):
    """A cold pool full of refcount-zero cached blocks must admit (and
    LRU-evict), not defer."""
    cfg, params = granite
    eng = _prefix_engine(cfg, [params], max_batch=1, max_seq=32,
                         prefix_cache=True)      # pool: 5 blocks of 8
    a = eng.submit(list(range(40, 64)), SamplingParams(max_new=8))
    eng.run()
    assert a.done
    bm = eng.block_mgr
    assert bm.n_cached > 0                       # finished request cached
    b = eng.submit(list(range(70, 86)), SamplingParams(max_new=8))
    eng.step()
    assert b.slot is not None                    # admitted, not deferred
    assert bm.evictions > 0
    eng.run()
    assert b.done and len(b.generated) == 8


def test_half_prefilled_request_survives_consolidation(granite):
    """§6.2 scale-down mid-prefill: the chunked request's committed
    blocks migrate, the remaining chunks run on the consolidated engine,
    and the stream is bit-exact with the single-worker reference."""
    cfg, params = granite
    m = build_model(cfg)
    long_prompt = list(range(3, 27))
    ref = _prefix_engine(cfg, [params])
    want = ref.submit(long_prompt, SamplingParams(max_new=6))
    ref.run()

    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(_prefix_engine(cfg, sp, prefix_cache=True,
                                        prefill_chunk=7))
    r = ep.submit(long_prompt, SamplingParams(max_new=6))
    ep.step()
    assert 0 < r.prefilled < r.prompt_total      # genuinely half-prefilled
    live = [x.rid for x in ep.active()]
    n_remote = ep.engine.n_attn_layers(migrated_only=True)
    quoted = ep.engine.block_mgr.migration_bytes(live, n_remote)
    ep.consolidate(params)
    assert ep.last_migration_bytes == quoted
    ep.run()
    assert r.generated == want.generated


def test_consolidation_ships_shared_blocks_once(granite):
    """Dedup-aware §6.2 accounting: with two in-flight requests sharing a
    2-block prefix, the gathered bytes equal the BlockManager quote and
    undercut the per-request (duplicated) block count."""
    cfg, params = granite
    m = build_model(cfg)
    sp = [m.slice_stage_params(params, 2, i) for i in range(2)]
    ep = ServingEndpoint(_prefix_engine(cfg, sp, prefix_cache=True))
    reqs = [ep.submit(SHARED_PREFIX + t, SamplingParams(max_new=6))
            for t in TAILS]
    for _ in range(2):
        ep.step()
    bm = ep.engine.block_mgr
    live_rids = [r.rid for r in ep.active()]
    n_remote = ep.engine.n_attn_layers(migrated_only=True)
    quoted = bm.migration_bytes(live_rids, n_remote)
    unique = len(bm.blocks_of(live_rids))
    duplicated = sum(len(bm.tables[r].blocks) for r in live_rids)
    assert unique < duplicated                   # sharing is real
    per_block = bm.block_size * bm.bytes_per_token * n_remote
    assert quoted == unique * per_block          # each shared block once
    ep.consolidate(params)
    assert ep.last_migration_bytes == quoted
    ep.run()
    # streams unaffected by dedup'd migration
    ref, _ = _run_pair(cfg, params)
    assert [r.generated for r in reqs] == [r.generated for r in ref]


def test_prefix_and_chunk_knobs_need_paged_attention_only(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, [params], paged=False, prefix_cache=True)
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, [params], paged=False, prefill_chunk=8)
    with pytest.raises(ValueError, match="prefill_chunk"):
        Engine(cfg, [params], paged=True, prefill_chunk=0)
    jcfg = smoke("jamba-v0.1-52b")               # hybrid: has mamba periods
    jp = build_model(jcfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-only"):
        Engine(jcfg, [jp], paged=True, prefix_cache=True)
